//! Scheduling types for the continuous-batching engine: the admission
//! queue ([`Batcher`] — the surviving piece of the old static batcher), the
//! admission policy, priority classes with deterministic logical-clock
//! aging, the preemption resume state, and the per-sequence in-flight
//! state.
//!
//! Everything here is pure bookkeeping (no model, no threads), so the
//! admission behavior is unit-testable in isolation; the model-touching
//! step loop lives in [`super::Engine`].

use crate::util::trace;
use std::collections::VecDeque;
use std::time::Instant;

/// Logical-clock ticks of queue wait that buy one rank of aging credit.
/// The engine ticks the queue once per step, so a request that has waited
/// `AGE_TICKS_PER_RANK` steps gains one effective rank; after
/// `(rank_gap + 1) × AGE_TICKS_PER_RANK` steps it strictly outranks every
/// fresher arrival of every tier. That bounds starvation for the low tiers
/// and for `ShortestPrompt` (a long prompt outranks fresh short ones after
/// one rank of credit) — property-tested below and in the engine's
/// integration tests.
pub const AGE_TICKS_PER_RANK: u64 = 16;

/// Scheduling class for a request. Higher tiers admit first under
/// contention; lower tiers are the preferred victims for preemption and
/// load shedding. Admission compares tiers through [`Priority::rank`] plus
/// a deterministic aging credit (see [`AGE_TICKS_PER_RANK`]), so low-tier
/// work is deprioritized, never starved.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Priority {
    /// Latency-sensitive traffic (chat turns): admits ahead of everything
    /// and may preempt lower tiers under page pressure.
    Interactive,
    /// The default tier for ordinary throughput work.
    #[default]
    Batch,
    /// Best-effort work (offline evals, cache warmers): first to be shed
    /// or preempted, protected from starvation only by aging.
    Background,
}

impl Priority {
    /// Base scheduling rank — higher admits first. Adjacent tiers are one
    /// rank apart, so one [`AGE_TICKS_PER_RANK`] wait promotes a request
    /// past a fresher request one tier up.
    pub fn rank(&self) -> u64 {
        match self {
            Priority::Interactive => 2,
            Priority::Batch => 1,
            Priority::Background => 0,
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Priority> {
        match s {
            "interactive" => Ok(Priority::Interactive),
            "batch" => Ok(Priority::Batch),
            "background" => Ok(Priority::Background),
            other => anyhow::bail!("unknown priority '{other}' (interactive|batch|background)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
            Priority::Background => "background",
        }
    }
}

/// Progress a preempted sequence carries back into the queue, replayed on
/// readmission: the tokens it had already generated rejoin the prefill
/// stream (their KV is recomputed — greedy decode from a recomputed prefix
/// is deterministic, so the completion stays bit-identical), generation
/// resumes after them, and the original admission/first-token stamps
/// survive so latency accounting spans the whole request, not just the
/// final residency.
#[derive(Debug, Default)]
pub struct ResumeState {
    /// True once the request has been preempted at least once (set even
    /// for mid-prefill victims with no generated tokens yet) — feeds the
    /// `victim_recompute_tokens` telemetry on readmission.
    pub preempted: bool,
    /// Tokens generated before preemption, in emission order.
    pub tokens: Vec<usize>,
    /// First-token stamp from the earlier residency, if one was emitted.
    pub first_token_at: Option<Instant>,
    /// The original admission stamp — queue wait is measured to the FIRST
    /// admission; preemption must not make a request look fresher.
    pub admitted: Option<Instant>,
}

/// An inference request.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub enqueued: Instant,
    /// Per-request generation budget; `None` ⇒ the server-wide
    /// `gen_tokens` default. The engine consumes it in the retire check
    /// and in the paged-arena reservation formula
    /// `ceil(min(len + gen − 1, seq_len) / page_size)`, so a short-budget
    /// request reserves fewer KV pages and admits alongside bigger ones.
    pub gen_tokens: Option<usize>,
    /// Opt into shared-prefix KV reuse (the default). When `false` this
    /// request neither maps published prefix pages at admission nor
    /// publishes its own — useful for privacy-sensitive prompts and for
    /// the bit-identity gates that compare shared vs unshared runs.
    pub share_prefix: bool,
    /// Generation stops early the moment any of these tokens is emitted;
    /// the stop token itself is included in the output (so the response is
    /// a prefix of the unstopped generation) and the response reports
    /// [`ResponseStatus::StoppedAtToken`].
    pub stop_tokens: Vec<usize>,
    /// Scheduling tier (see [`Priority`]); defaults to [`Priority::Batch`].
    pub priority: Priority,
    /// The [`Batcher`] logical-clock value when this request was pushed —
    /// the base the aging credit is measured from. Stamped by
    /// [`Batcher::push`]; preserved verbatim across preemption requeues.
    pub arrived_tick: u64,
    /// Saved progress from a preempted residency (empty for fresh
    /// requests).
    pub resume: ResumeState,
}

impl Request {
    /// A request with the server-default generation budget, enqueued now.
    pub fn new(id: u64, prompt: Vec<usize>) -> Request {
        trace::instant_args("request_enqueued", &[("id", id as f64)]);
        Request {
            id,
            prompt,
            enqueued: Instant::now(),
            gen_tokens: None,
            share_prefix: true,
            stop_tokens: Vec::new(),
            priority: Priority::default(),
            arrived_tick: 0,
            resume: ResumeState::default(),
        }
    }

    /// Attach a per-request generation budget.
    pub fn with_budget(mut self, gen_tokens: usize) -> Request {
        self.gen_tokens = Some(gen_tokens);
        self
    }

    /// Attach per-request stop tokens.
    pub fn with_stop_tokens(mut self, stop_tokens: Vec<usize>) -> Request {
        self.stop_tokens = stop_tokens;
        self
    }

    /// Attach a scheduling tier.
    pub fn with_priority(mut self, priority: Priority) -> Request {
        self.priority = priority;
        self
    }

    /// Opt this request out of shared-prefix KV reuse.
    pub fn without_prefix_sharing(mut self) -> Request {
        self.share_prefix = false;
        self
    }

    /// The generation budget this request runs under, given the
    /// server-wide default.
    pub fn budget(&self, default_gen: usize) -> usize {
        self.gen_tokens.unwrap_or(default_gen)
    }

    /// Length of the prefill stream on (re)admission: the prompt plus any
    /// tokens a previous residency already generated (recomputed after a
    /// preemption). Admission sizes KV reservations and the slot-free
    /// rejection fast path against this, not the bare prompt.
    pub fn prefill_len(&self) -> usize {
        self.prompt.len() + self.resume.tokens.len()
    }
}

/// How a request left the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResponseStatus {
    /// Served to its full generation budget.
    Complete,
    /// The prompt exceeded the model's `seq_len`; the request was rejected
    /// without prefill instead of being silently truncated.
    Truncated,
    /// Generation stopped because the KV cache filled (`seq_len` reached)
    /// before the generation budget did — truncated-by-memory, not done.
    /// Clients see fewer tokens than they asked for and can tell this
    /// apart from a budget-complete response.
    CapacityStopped,
    /// Generation ended because a [`Request::stop_tokens`] entry was
    /// emitted before the budget ran out. The stop token is the last
    /// output token. Takes precedence over `Complete` when the stop fires
    /// exactly on the budget's final token — the stop predicate matched,
    /// whatever the budget said.
    StoppedAtToken,
    /// Dropped from the queue by the SLO-aware load shedder: under
    /// overload the engine sacrifices the lowest-priority queued work so
    /// admitted requests keep their first-token SLO instead of the whole
    /// queue missing it. The response carries no new tokens (only the
    /// pre-preemption tokens, if the request had run before).
    Shed,
}

/// Per-step admission order for queued requests. Both policies rank by
/// aged priority first (see [`Batcher::effective_rank`]); the policy only
/// decides the tie-break within the top rank.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// First come, first served among the top aged rank.
    #[default]
    Fcfs,
    /// Shortest prompt first (FIFO among equals) within the top aged rank
    /// — favors fast first tokens for cheap requests under a backlog.
    /// Aging bounds the starvation this used to inflict on long prompts:
    /// after [`AGE_TICKS_PER_RANK`] waited steps a long prompt outranks
    /// every fresher short one.
    ShortestPrompt,
}

impl AdmissionPolicy {
    pub fn parse(s: &str) -> anyhow::Result<AdmissionPolicy> {
        match s {
            "fcfs" => Ok(AdmissionPolicy::Fcfs),
            "shortest" => Ok(AdmissionPolicy::ShortestPrompt),
            other => anyhow::bail!("unknown admission policy '{other}' (fcfs|shortest)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Fcfs => "fcfs",
            AdmissionPolicy::ShortestPrompt => "shortest",
        }
    }
}

/// What the engine sheds when the predicted first-token wait for queued
/// work exceeds the configured SLO.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Never shed; overload shows up as queue wait (the prior behavior,
    /// and the right setting for bit-identity A/B runs, where shed
    /// decisions would otherwise diverge between the arms).
    #[default]
    Off,
    /// Shed the newest request of the lowest base tier until the predicted
    /// wait fits the SLO — admitted work keeps its SLO instead of the
    /// whole queue missing it.
    LowestPriority,
}

impl ShedPolicy {
    pub fn parse(s: &str) -> anyhow::Result<ShedPolicy> {
        match s {
            "off" => Ok(ShedPolicy::Off),
            "lowest" => Ok(ShedPolicy::LowestPriority),
            other => anyhow::bail!("unknown shed policy '{other}' (off|lowest)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShedPolicy::Off => "off",
            ShedPolicy::LowestPriority => "lowest",
        }
    }
}

/// The admission queue: requests wait here until the engine has a free KV
/// slot. (This is what remains of the old dynamic batcher — batch *shape*
/// is no longer decided here; the engine re-forms its decode batch every
/// step from whatever sequences are resident.)
#[derive(Default)]
pub struct Batcher {
    queue: VecDeque<Request>,
    /// Deterministic logical clock: ticked once per engine step (never
    /// wall time), it stamps [`Request::arrived_tick`] at push and drives
    /// the aging credit in [`Batcher::effective_rank`].
    clock: u64,
}

impl Batcher {
    pub fn push(&mut self, mut req: Request) {
        req.arrived_tick = self.clock;
        self.queue.push_back(req);
    }

    /// Advance the logical clock by one engine step.
    pub fn tick(&mut self) {
        self.clock += 1;
    }

    /// The current logical-clock value (steps since the queue was built).
    pub fn clock(&self) -> u64 {
        self.clock
    }

    /// Return a preempted request to the FRONT of the queue, keeping its
    /// original [`Request::arrived_tick`] (and so its accumulated aging
    /// credit): preemption must not reset a victim's place in line.
    pub fn reinsert(&mut self, req: Request) {
        self.queue.push_front(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Iterate queued requests in arrival order (front = oldest) — the
    /// engine's shed-time backlog predictor walks this to estimate queue
    /// wait without disturbing the queue.
    pub fn iter(&self) -> impl Iterator<Item = &Request> {
        self.queue.iter()
    }

    /// Remove every queued request matching `pred`, preserving FIFO order
    /// among the kept ones — the engine's slot-free fast path: requests
    /// that can be answered without a KV slot (rejections, trivially
    /// empty completions) must not wait behind a full arena. The common
    /// no-match case is a single allocation-free scan, so calling this
    /// every engine step is cheap under a backlog; `pred` must be pure
    /// (it runs twice on matching queues).
    pub fn take_where(&mut self, mut pred: impl FnMut(&Request) -> bool) -> Vec<Request> {
        if !self.queue.iter().any(&mut pred) {
            return Vec::new();
        }
        let mut taken = Vec::new();
        let mut kept = VecDeque::with_capacity(self.queue.len());
        for r in self.queue.drain(..) {
            if pred(&r) {
                taken.push(r);
            } else {
                kept.push_back(r);
            }
        }
        self.queue = kept;
        taken
    }

    /// The rank admission actually compares: the tier's base rank plus one
    /// rank per [`AGE_TICKS_PER_RANK`] ticks waited. Monotone in wait, so
    /// every queued request eventually outranks all fresher arrivals —
    /// the starvation bound for `ShortestPrompt` and the low tiers.
    pub fn effective_rank(&self, req: &Request) -> u64 {
        req.priority.rank() + self.clock.saturating_sub(req.arrived_tick) / AGE_TICKS_PER_RANK
    }

    /// Index of the next request `policy` would admit, if any: the highest
    /// aged rank, tie-broken by the policy (FCFS: earliest; shortest:
    /// cheapest prompt, FIFO among equals).
    fn next_index(&self, policy: AdmissionPolicy) -> Option<usize> {
        use std::cmp::Reverse;
        match policy {
            AdmissionPolicy::Fcfs => self
                .queue
                .iter()
                .enumerate()
                .max_by_key(|(i, r)| (self.effective_rank(r), Reverse(*i)))
                .map(|(i, _)| i),
            AdmissionPolicy::ShortestPrompt => self
                .queue
                .iter()
                .enumerate()
                .max_by_key(|(i, r)| (self.effective_rank(r), Reverse((r.prompt.len(), *i))))
                .map(|(i, _)| i),
        }
    }

    /// Remove the next request under `policy`, if any.
    pub fn pop(&mut self, policy: AdmissionPolicy) -> Option<Request> {
        let idx = self.next_index(policy)?;
        self.queue.remove(idx)
    }

    /// The request `policy` would admit next, without removing it — the
    /// engine inspects it (prefix match, page-need computation, index
    /// eviction under pressure) before committing to the admission.
    /// `next_index` is deterministic, so a [`Batcher::pop`] with no
    /// intervening queue mutation removes exactly this request.
    pub fn peek(&self, policy: AdmissionPolicy) -> Option<&Request> {
        self.next_index(policy).map(|i| &self.queue[i])
    }

    /// Remove the next request under `policy` only if `admit` accepts it.
    /// A rejected head blocks this admission pass rather than being
    /// skipped: later (smaller) requests never jump an earlier one that is
    /// waiting for KV pages, so a big request cannot be starved by a
    /// stream of small ones — and because the head's worst-case page need
    /// is bounded by one full sequence (which the pool is required to
    /// hold), it always fits once enough residents retire.
    pub fn pop_where(
        &mut self,
        policy: AdmissionPolicy,
        admit: impl FnOnce(&Request) -> bool,
    ) -> Option<Request> {
        let idx = self.next_index(policy)?;
        if admit(&self.queue[idx]) {
            self.queue.remove(idx)
        } else {
            None
        }
    }

    /// Remove the queued request the load shedder should drop: the NEWEST
    /// request of the LOWEST base tier (aging credit deliberately ignored
    /// — shedding is about who loses least, and the newest low-tier
    /// arrival has sunk the least wait). Returns `None` on an empty queue.
    pub fn shed_pop(&mut self) -> Option<Request> {
        let idx = self
            .queue
            .iter()
            .enumerate()
            .min_by_key(|(i, r)| (r.priority.rank(), std::cmp::Reverse(*i)))
            .map(|(i, _)| i)?;
        self.queue.remove(idx)
    }
}

/// One in-flight sequence: its KV slot, prefill cursor, last logits,
/// generated tokens, and resolved generation budget.
pub struct Sequence {
    pub id: u64,
    /// The full prefill stream for this residency: the original prompt
    /// plus any tokens a preempted earlier residency had already generated
    /// (those are re-prefilled to rebuild their KV — see [`ResumeState`]).
    pub prompt: Vec<usize>,
    /// Index into the engine's [`super::KvPool`].
    pub slot: usize,
    /// Next prompt position to prefill; `== prompt.len()` once decoding.
    /// The prefix-reuse admission path starts this past the shared pages
    /// (the tokens whose KV already exists are never re-prefilled).
    pub next_prefill: usize,
    /// Logits from this sequence's latest decode step.
    pub logits: Vec<f32>,
    pub out: Vec<usize>,
    /// Tokens to generate — the per-request budget, or the server default
    /// resolved at admission (the engine's retire check reads this).
    pub budget: usize,
    /// Shared-prefix participation, carried from the request.
    pub share_prefix: bool,
    /// Prompt pages this sequence has published to the prefix index so
    /// far (the publish cursor — pages `0..published` are done).
    pub published: usize,
    /// Stop tokens, carried from the request (the engine's retire check
    /// reads these next to the budget).
    pub stop_tokens: Vec<usize>,
    /// Scheduling tier, carried from the request — the preemption victim
    /// order and the per-tier latency summaries read this.
    pub priority: Priority,
    /// Arrival tick, carried from the request — survives preemption so a
    /// requeued victim keeps its aging credit, and feeds the SLO
    /// goodput check at first-token time.
    pub arrived_tick: u64,
    /// How many of `out`'s leading tokens were resumed from a preempted
    /// residency (re-prefilled, not re-emitted) — `prompt`'s last
    /// `resumed` tokens are exactly these.
    pub resumed: usize,
    pub enqueued: Instant,
    /// When the engine FIRST admitted this request into a KV slot (stamped
    /// in [`Sequence::new`], restored across preemptions); `admitted −
    /// enqueued` is the queue wait the serve layer summarizes.
    pub admitted: Instant,
    pub first_token_at: Option<Instant>,
}

impl Sequence {
    pub fn new(req: Request, slot: usize, vocab: usize, default_gen: usize) -> Sequence {
        let budget = req.budget(default_gen);
        let resumed = req.resume.tokens.len();
        let mut prompt = req.prompt;
        prompt.extend_from_slice(&req.resume.tokens);
        Sequence {
            id: req.id,
            prompt,
            slot,
            next_prefill: 0,
            logits: vec![0.0; vocab],
            out: req.resume.tokens,
            budget,
            share_prefix: req.share_prefix,
            published: 0,
            stop_tokens: req.stop_tokens,
            priority: req.priority,
            arrived_tick: req.arrived_tick,
            resumed,
            enqueued: req.enqueued,
            admitted: req.resume.admitted.unwrap_or_else(Instant::now),
            first_token_at: req.resume.first_token_at,
        }
    }

    /// Still consuming prompt tokens?
    pub fn prefilling(&self) -> bool {
        self.next_prefill < self.prompt.len()
    }

    /// True when the most recent output token is one of this request's
    /// stop tokens — the retire check's token predicate, evaluated next to
    /// the budget.
    pub fn stopped_at_token(&self) -> bool {
        self.out.last().is_some_and(|t| self.stop_tokens.contains(t))
    }

    /// Tear this in-flight sequence back down into a queued request — the
    /// preemption path. The KV slot is NOT released here (the engine does
    /// that against the pool); all scheduling state survives: original
    /// prompt, resolved budget, tier, arrival tick, and the
    /// generated-so-far tokens that prefill recomputes on readmission.
    pub fn into_request(mut self) -> Request {
        let orig = self.prompt.len() - self.resumed;
        self.prompt.truncate(orig);
        Request {
            id: self.id,
            prompt: self.prompt,
            enqueued: self.enqueued,
            gen_tokens: Some(self.budget),
            share_prefix: self.share_prefix,
            stop_tokens: self.stop_tokens,
            priority: self.priority,
            arrived_tick: self.arrived_tick,
            resume: ResumeState {
                preempted: true,
                tokens: self.out,
                first_token_at: self.first_token_at,
                admitted: Some(self.admitted),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, len: usize) -> Request {
        Request::new(id, vec![1; len])
    }

    #[test]
    fn budget_resolves_against_default() {
        let r = req(0, 2);
        assert_eq!(r.budget(16), 16, "no per-request budget ⇒ server default");
        let r = req(1, 2).with_budget(3);
        assert_eq!(r.budget(16), 3);
        let r = req(2, 2).with_budget(0);
        assert_eq!(r.budget(16), 0, "explicit zero budget is honored");
    }

    #[test]
    fn fcfs_pops_in_arrival_order() {
        let mut b = Batcher::default();
        for i in 0..5 {
            b.push(req(i, (5 - i) as usize));
        }
        let ids: Vec<u64> = (0..5).map(|_| b.pop(AdmissionPolicy::Fcfs).unwrap().id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        assert!(b.pop(AdmissionPolicy::Fcfs).is_none());
    }

    #[test]
    fn shortest_prompt_pops_cheapest_first_fifo_on_ties() {
        let mut b = Batcher::default();
        b.push(req(0, 4));
        b.push(req(1, 2));
        b.push(req(2, 2));
        b.push(req(3, 1));
        let ids: Vec<u64> =
            (0..4).map(|_| b.pop(AdmissionPolicy::ShortestPrompt).unwrap().id).collect();
        assert_eq!(ids, vec![3, 1, 2, 0], "shortest first, FIFO among equal lengths");
    }

    #[test]
    fn pop_conserves_requests() {
        let mut b = Batcher::default();
        for i in 0..7 {
            b.push(req(i, i as usize % 3));
        }
        let mut seen = std::collections::HashSet::new();
        while let Some(r) = b.pop(AdmissionPolicy::ShortestPrompt) {
            assert!(seen.insert(r.id), "request popped twice");
        }
        assert_eq!(seen.len(), 7);
        assert!(b.is_empty());
    }

    #[test]
    fn take_where_extracts_and_preserves_order() {
        let mut b = Batcher::default();
        for i in 0..6 {
            b.push(req(i, i as usize));
        }
        let taken = b.take_where(|r| r.prompt.len() % 2 == 0);
        assert_eq!(taken.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2, 4]);
        assert_eq!(b.len(), 3);
        let rest: Vec<u64> = (0..3).map(|_| b.pop(AdmissionPolicy::Fcfs).unwrap().id).collect();
        assert_eq!(rest, vec![1, 3, 5], "kept requests stay FIFO");
    }

    #[test]
    fn pop_where_blocks_on_rejected_head() {
        let mut b = Batcher::default();
        b.push(req(0, 9)); // big head
        b.push(req(1, 1)); // small follower
        // FCFS: the big head is rejected and the small one must NOT jump it.
        assert!(b.pop_where(AdmissionPolicy::Fcfs, |r| r.prompt.len() <= 4).is_none());
        assert_eq!(b.len(), 2, "rejected head stays queued");
        let got = b.pop_where(AdmissionPolicy::Fcfs, |r| r.prompt.len() <= 9).unwrap();
        assert_eq!(got.id, 0);
        // ShortestPrompt: the policy's own pick is the one gated.
        b.push(req(2, 5));
        let got = b.pop_where(AdmissionPolicy::ShortestPrompt, |_| true).unwrap();
        assert_eq!(got.id, 1, "shortest prompt admitted first");
        assert!(b.pop_where(AdmissionPolicy::ShortestPrompt, |_| false).is_none());
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn policy_parse_round_trips() {
        for p in [AdmissionPolicy::Fcfs, AdmissionPolicy::ShortestPrompt] {
            assert_eq!(AdmissionPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(AdmissionPolicy::parse("lifo").is_err());
    }

    #[test]
    fn priority_and_shed_policy_parse_round_trip() {
        for p in [Priority::Interactive, Priority::Batch, Priority::Background] {
            assert_eq!(Priority::parse(p.name()).unwrap(), p);
        }
        assert!(Priority::parse("vip").is_err());
        assert_eq!(Priority::default(), Priority::Batch);
        for s in [ShedPolicy::Off, ShedPolicy::LowestPriority] {
            assert_eq!(ShedPolicy::parse(s.name()).unwrap(), s);
        }
        assert!(ShedPolicy::parse("all").is_err());
    }

    #[test]
    fn aging_promotes_starved_background_past_fresh_interactive() {
        let mut b = Batcher::default();
        b.push(req(0, 8).with_priority(Priority::Background));
        b.push(req(1, 8).with_priority(Priority::Interactive));
        assert_eq!(b.peek(AdmissionPolicy::Fcfs).unwrap().id, 1, "interactive outranks when fresh");
        // Background (rank 0) vs Interactive (rank 2): three ranks of aging
        // credit make the old request strictly dominate any FRESH arrival.
        for _ in 0..3 * AGE_TICKS_PER_RANK {
            b.tick();
        }
        let first = b.pop(AdmissionPolicy::Fcfs).unwrap().id;
        assert_eq!(first, 1, "equally-aged peers keep tier order");
        b.push(req(2, 8).with_priority(Priority::Interactive));
        assert_eq!(
            b.pop(AdmissionPolicy::Fcfs).unwrap().id,
            0,
            "aged background strictly outranks a fresh interactive arrival"
        );
    }

    #[test]
    fn adversarial_short_stream_cannot_starve_a_long_prompt() {
        // Regression for ShortestPrompt starvation: a long prompt queued
        // behind an endless stream of fresh short prompts must admit within
        // the aging bound — one AGE_TICKS_PER_RANK wait buys a same-tier
        // rank, which beats any fresh arrival's length advantage.
        let mut b = Batcher::default();
        b.push(req(0, 64)); // the long prompt short arrivals used to jump
        let mut admitted_at = None;
        for t in 0..2 * AGE_TICKS_PER_RANK {
            b.tick();
            b.push(req(1000 + t, 1)); // fresh adversarial short prompt
            if b.pop(AdmissionPolicy::ShortestPrompt).unwrap().id == 0 {
                admitted_at = Some(t);
                break;
            }
        }
        let t = admitted_at.expect("long prompt starved past the aging bound");
        assert!(t <= AGE_TICKS_PER_RANK, "admitted within one aging rank, got {t}");
    }

    #[test]
    fn aged_ordering_is_deterministic() {
        let run = || {
            let mut b = Batcher::default();
            let prios = [Priority::Background, Priority::Interactive, Priority::Batch];
            for i in 0..12u64 {
                b.push(req(i, 1 + (i as usize * 5) % 7).with_priority(prios[(i % 3) as usize]));
                for _ in 0..(i % 4) {
                    b.tick();
                }
            }
            let mut order = Vec::new();
            while let Some(r) = b.pop(AdmissionPolicy::Fcfs) {
                order.push(r.id);
                b.tick();
            }
            order
        };
        let order = run();
        assert_eq!(order.len(), 12);
        assert_eq!(run(), order, "same push/tick/pop script ⇒ same order (logical clock only)");
        assert_eq!(order[0], 1, "the oldest interactive request pops first");
    }

    #[test]
    fn reinsert_keeps_arrival_tick_and_goes_to_front() {
        let mut b = Batcher::default();
        b.push(req(0, 2));
        for _ in 0..5 {
            b.tick();
        }
        b.push(req(1, 2));
        let head = b.pop(AdmissionPolicy::Fcfs).unwrap();
        assert_eq!(head.id, 0);
        assert_eq!(head.arrived_tick, 0);
        b.reinsert(head);
        let again = b.pop(AdmissionPolicy::Fcfs).unwrap();
        assert_eq!(again.id, 0, "reinserted request returns to the head");
        assert_eq!(again.arrived_tick, 0, "reinsert keeps the original arrival tick");
    }

    #[test]
    fn shed_pop_drops_newest_lowest_tier_first() {
        let mut b = Batcher::default();
        b.push(req(0, 2).with_priority(Priority::Background));
        b.push(req(1, 2).with_priority(Priority::Interactive));
        b.push(req(2, 2).with_priority(Priority::Background));
        b.push(req(3, 2).with_priority(Priority::Batch));
        assert_eq!(b.shed_pop().unwrap().id, 2, "newest background sheds first");
        assert_eq!(b.shed_pop().unwrap().id, 0);
        assert_eq!(b.shed_pop().unwrap().id, 3, "then batch");
        assert_eq!(b.shed_pop().unwrap().id, 1, "interactive sheds last");
        assert!(b.shed_pop().is_none());
    }

    #[test]
    fn preemption_round_trips_through_into_request() {
        let r = req(7, 3).with_priority(Priority::Interactive).with_budget(6);
        let mut s = Sequence::new(r, 0, 4, 16);
        assert_eq!(s.budget, 6);
        s.out = vec![9, 8];
        s.next_prefill = s.prompt.len();
        let first = Some(s.admitted);
        s.first_token_at = first;
        let admitted = s.admitted;
        let rq = s.into_request();
        assert_eq!(rq.prompt, vec![1, 1, 1], "original prompt survives the requeue");
        assert_eq!(rq.resume.tokens, vec![9, 8]);
        assert!(rq.resume.preempted);
        assert_eq!(rq.gen_tokens, Some(6), "budget pinned to the value resolved at admission");
        assert_eq!(rq.priority, Priority::Interactive);
        assert_eq!(rq.prefill_len(), 5);
        // Readmission: the generated tokens rejoin the prefill stream and
        // the original stamps survive.
        let s2 = Sequence::new(rq, 3, 4, 16);
        assert_eq!(s2.prompt, vec![1, 1, 1, 9, 8]);
        assert_eq!(s2.out, vec![9, 8]);
        assert_eq!(s2.resumed, 2);
        assert_eq!(s2.admitted, admitted, "queue wait still measured to the FIRST admission");
        assert_eq!(s2.first_token_at, first);
        assert!(s2.prefilling());
    }
}
