//! The continuous-batching decode engine.
//!
//! Unlike the old batch-at-a-time worker loop (form a batch, run it to
//! completion, only then look at the queue again), this engine re-forms its
//! working set **every step**:
//!
//! 1. **Admit** — queued requests are pulled into free KV slots
//!    ([`KvPool`], a fixed **paged** arena preallocated at startup) under
//!    the configured [`AdmissionPolicy`]. Admission is page-aware: a
//!    joiner needs a free slot *and* a worst-case page reservation
//!    (`ceil(min(prompt + gen − 1, seq_len) / page_size)` — its prompt
//!    pages plus decode headroom, where `gen` is the request's own
//!    [`Request::gen_tokens`] budget or the server default), so a
//!    resident sequence can always grow to retirement and admission can
//!    never deadlock mid-generation; short-budget requests reserve fewer
//!    pages and admit alongside bigger ones.
//!    Requests that can never generate (empty prompts, zero budgets) are
//!    answered immediately without a slot — even while the arena is
//!    full — prompts longer than the model's `seq_len` are rejected with
//!    [`ResponseStatus::Truncated`] instead of being silently cut, and
//!    prompts that exactly fill the KV capacity come back empty as
//!    [`ResponseStatus::CapacityStopped`].
//! 2. **Chunked prefill** — joining sequences consume up to
//!    `prefill_chunk` prompt tokens, batched across all joiners through
//!    [`TransformerLM::decode_step_batch`] (the same lockstep kernel path
//!    decode uses, so prefill work also runs the packed [b × d] kernels).
//! 3. **Lockstep decode** — every resident sequence with a completed
//!    prefill emits one token and advances its KV cache one position.
//! 4. **Retire** — finished sequences release their slot and a second
//!    admission pass refills freed slots *in the same step*, so the decode
//!    batch never runs below occupancy while work is queued.
//!
//! Every step's arithmetic is [`decode_step_batch`], whose per-row
//! results are independent of batch composition — so per-sequence outputs
//! never depend on which requests happened to share a step. For dense
//! models that makes them bit-identical to scalar [`generate`]
//! (property-tested under randomized arrivals in
//! `rust/tests/serve_engine.rs`); for packed/compressed models the
//! batched kernels can differ from the scalar `decode_step` path in the
//! last ulps, and the batch-of-1 reference is
//! [`generate_lockstep`].
//!
//! [`TransformerLM::decode_step_batch`]: crate::model::TransformerLM::decode_step_batch
//! [`decode_step_batch`]: crate::model::TransformerLM::decode_step_batch
//! [`generate`]: crate::coordinator::serve::generate
//! [`generate_lockstep`]: crate::coordinator::serve::generate_lockstep

pub mod kv_pool;
pub mod prefix;
pub mod sched;

pub use kv_pool::KvPool;
pub use prefix::PrefixIndex;
pub use sched::{
    AdmissionPolicy, Batcher, Priority, Request, ResponseStatus, Sequence, ShedPolicy,
};

use crate::model::TransformerLM;
use crate::sparse::Workspace;
use crate::tensor::argmax;
use crate::util::trace;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Distinguishes engines within one process in trace event args (tests and
/// benches often run several engines; trace ids keep their lifecycle
/// instants separable after a global drain).
static NEXT_ENGINE_ID: AtomicU64 = AtomicU64::new(1);

/// Engine knobs (the serving-layer [`ServeConfig`] derives one of these).
///
/// [`ServeConfig`]: crate::coordinator::serve::ServeConfig
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// KV-slot arena size — the hard bound on resident sequences and on
    /// the decode batch width (and therefore the kernel `batch_hint`).
    pub slots: usize,
    /// Max prompt tokens a joining sequence consumes per engine step.
    pub prefill_chunk: usize,
    /// Default tokens to generate per request; a request carrying its own
    /// [`Request::gen_tokens`] budget overrides this for that request.
    pub gen_tokens: usize,
    pub admission: AdmissionPolicy,
    /// KV positions per page. `0` ⇒ whole-sequence pages (`seq_len`): the
    /// contiguous degenerate layout, exactly the pre-paging behavior.
    pub page_size: usize,
    /// Total pages in the arena. `0` ⇒ `slots × ceil(seq_len/page_size)`
    /// (every slot can hold a full sequence — byte-equivalent to the
    /// whole-cache arena). Values below one full sequence are raised to
    /// that minimum so any admissible request can always be served.
    pub kv_pages: usize,
    /// Max entries the prefix index keeps resident (`0` ⇒ unbounded).
    /// Overflow evicts least-recently-used unreferenced entries on a
    /// deterministic logical clock; evicted pages return to the pool and
    /// count as `prefix_evictions_cap` in the telemetry.
    pub prefix_cap: usize,
    /// Allow admission to evict a resident victim when the queue's next
    /// pick STRICTLY outranks it by base tier and no pages (or slots) are
    /// otherwise available. The victim releases every page it holds and
    /// re-queues with its generated tokens saved; readmission re-prefills
    /// them, and greedy decode from the recomputed prefix is
    /// deterministic, so completions stay bit-identical to a
    /// preemption-off run.
    pub preemption: bool,
    /// First-token SLO in engine steps (logical clock, measured from the
    /// request's arrival tick). `0` ⇒ no SLO: every first token counts as
    /// goodput and the shedder never fires.
    pub slo_first_token_steps: usize,
    /// What to shed when the predicted queue wait exceeds the SLO.
    pub shed_policy: ShedPolicy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            slots: 8,
            prefill_chunk: 8,
            gen_tokens: 16,
            admission: AdmissionPolicy::Fcfs,
            page_size: 0,
            kv_pages: 0,
            prefix_cap: 0,
            preemption: false,
            slo_first_token_steps: 0,
            shed_policy: ShedPolicy::Off,
        }
    }
}

/// What happened to sequences during one engine step.
#[derive(Debug)]
pub enum SeqEvent {
    /// A token was generated (streamed to the caller before the sequence
    /// finishes). `first` marks the sequence's first generated token.
    Token { id: u64, token: usize, first: bool },
    Finished(FinishedSeq),
}

/// A retired sequence, ready to become a response.
#[derive(Debug)]
pub struct FinishedSeq {
    pub id: u64,
    pub tokens: Vec<usize>,
    pub status: ResponseStatus,
    /// Scheduling tier the request ran (or was shed) under — the serve
    /// layer's per-tier latency summaries bucket on this.
    pub priority: Priority,
    pub enqueued: Instant,
    /// Time from enqueue to admission (for slot-free answers: to the
    /// answering step) — the component of first-token latency that is
    /// queueing, not compute.
    pub queue_wait: Duration,
    pub first_token_latency: Option<Duration>,
}

/// Cap on the per-step sample vectors below: once a vector reaches twice
/// this, the oldest half is dropped (amortized O(1)), so a long-running
/// server's telemetry memory stays bounded while summaries always cover
/// at least the most recent `TELEMETRY_WINDOW` steps. The scalar counters
/// (`steps`/`joins`/`leaves`/`truncated`) remain lifetime totals.
pub const TELEMETRY_WINDOW: usize = 16_384;

/// Per-step engine telemetry. Counters are lifetime totals; the per-step
/// sample vectors cover the most recent [`TELEMETRY_WINDOW`]..2× steps.
#[derive(Clone, Debug, Default)]
pub struct EngineTelemetry {
    /// Arena size (denominator for `occupancy`).
    pub slots: usize,
    /// KV positions per page.
    pub page_size: usize,
    /// Total pages in the arena (denominator for `page_occupancy`).
    pub total_pages: usize,
    /// Steps that did any work — decode, prefill, or slot-free answers
    /// (idle polls are not counted).
    pub steps: usize,
    /// Sequences admitted into a KV slot. A preempted victim's
    /// readmission counts again, pairing with the `leave` its eviction
    /// recorded — so `joins == leaves` holds exactly at drain.
    pub joins: usize,
    /// Sequences that vacated a KV slot (retirement or preemption).
    pub leaves: usize,
    /// Requests rejected for oversized prompts.
    pub truncated: usize,
    /// Requests whose generation was stopped by KV capacity rather than
    /// by reaching the budget ([`ResponseStatus::CapacityStopped`]).
    pub capacity_stopped: usize,
    /// Residents evicted mid-flight so a strictly higher-tier request
    /// could admit; each re-queued with its generated tokens saved.
    pub preemptions: usize,
    /// Queued requests dropped by the SLO-aware load shedder
    /// ([`ResponseStatus::Shed`]).
    pub shed: usize,
    /// Tokens re-prefilled on readmission of preempted victims (their KV
    /// was released at eviction) — the recompute cost preemption trades
    /// for priority inversion.
    pub victim_recompute_tokens: usize,
    /// First tokens emitted within `slo_first_token_steps` of arrival
    /// (every first token when no SLO is set) — the numerator of the
    /// serve layer's `goodput_under_slo`.
    pub slo_hits: usize,
    /// Decode-batch width per step.
    pub decode_batch: Vec<f64>,
    /// Occupied-slot fraction per step (sampled after same-step backfill).
    pub occupancy: Vec<f64>,
    /// Admission-queue depth per step (sampled after admission).
    pub queue_depth: Vec<f64>,
    /// Pages attached to resident sequences, per step.
    pub pages_in_use: Vec<f64>,
    /// Held-page fraction per step (`pages_in_use / total_pages`).
    pub page_occupancy: Vec<f64>,
    /// Pages held as of the most recent step — `0` once the engine has
    /// drained, which is the leak check the serve JSON exposes.
    pub pages_in_use_now: usize,
    /// Constant KV-arena footprint in bytes (set at engine startup).
    pub kv_bytes: usize,
    /// Fresh heap buffers the decode workspace has ever allocated
    /// (lifetime total). Flat across steps once shapes have been seen —
    /// the "decode no longer allocates xt/out per call" regression check.
    pub ws_buffer_allocs: usize,
    /// Prompt tokens admission skipped because their KV already existed as
    /// shared prefix pages (lifetime total) — the work shared-prefix reuse
    /// saves.
    pub prefill_tokens_saved: usize,
    /// Shared prefix pages mapped into joiners at admission (lifetime
    /// total of mappings, not distinct pages).
    pub shared_pages: usize,
    /// Copy-on-write forks: writes that landed inside a shared page and
    /// had to copy it into sequence-owned storage first (lifetime total).
    pub cow_forks: usize,
    /// Prefix-index entries LRU-evicted to honor the configured capacity
    /// cap (lifetime total). Distinct from page-pressure eviction, which
    /// is demand-driven and uncounted here.
    pub prefix_evictions_cap: usize,
    /// Wall-clock spent in admission (both passes: admit + same-step
    /// backfill), lifetime total in seconds. Always measured — the phase
    /// clocks do not depend on the trace flag.
    pub time_admit_s: f64,
    /// Wall-clock spent in chunked prefill (including prefix-page
    /// publishing), lifetime total in seconds.
    pub time_prefill_s: f64,
    /// Wall-clock spent in lockstep decode, lifetime total in seconds.
    pub time_decode_s: f64,
    /// Wall-clock spent retiring finished sequences, lifetime total in
    /// seconds.
    pub time_retire_s: f64,
    /// Whole-step wall-clock, lifetime total in seconds. The four phase
    /// totals above sum to at most this (the remainder is bookkeeping:
    /// drain flush, telemetry, debug audits).
    pub time_step_s: f64,
}

impl EngineTelemetry {
    /// Enforce the [`TELEMETRY_WINDOW`] bound on the sample vectors.
    fn trim(&mut self) {
        for v in [
            &mut self.decode_batch,
            &mut self.occupancy,
            &mut self.queue_depth,
            &mut self.pages_in_use,
            &mut self.page_occupancy,
        ] {
            if v.len() >= 2 * TELEMETRY_WINDOW {
                let excess = v.len() - TELEMETRY_WINDOW;
                v.drain(..excess);
            }
        }
    }
}

/// What one engine step did, folded into the telemetry under a single
/// end-of-step lock.
#[derive(Clone, Copy, Default)]
struct StepCounts {
    joins: usize,
    truncated: usize,
    capacity_stopped: usize,
    leaves: usize,
    preemptions: usize,
    shed: usize,
    victim_recompute_tokens: usize,
    slo_hits: usize,
    prefill_tokens_saved: usize,
    shared_pages: usize,
    cow_forks: usize,
    prefix_evictions_cap: usize,
}

/// Per-phase wall-clock for one engine step, folded into the telemetry
/// alongside [`StepCounts`]. Measured unconditionally (plain `Instant`
/// reads at phase boundaries) so the SERVE json breakdown exists even with
/// tracing off.
#[derive(Clone, Copy, Default)]
struct PhaseTimes {
    admit: f64,
    prefill: f64,
    decode: f64,
    retire: f64,
    step: f64,
}

impl StepCounts {
    fn absorb(&mut self, other: StepCounts) {
        self.joins += other.joins;
        self.truncated += other.truncated;
        self.capacity_stopped += other.capacity_stopped;
        self.leaves += other.leaves;
        self.preemptions += other.preemptions;
        self.shed += other.shed;
        self.victim_recompute_tokens += other.victim_recompute_tokens;
        self.slo_hits += other.slo_hits;
        self.prefill_tokens_saved += other.prefill_tokens_saved;
        self.shared_pages += other.shared_pages;
        self.cow_forks += other.cow_forks;
        self.prefix_evictions_cap += other.prefix_evictions_cap;
    }
}

/// The engine: model + KV arena + resident sequences. Single-threaded by
/// design — the serving layer owns it on one thread and the kernels below
/// provide the parallelism — which also makes it directly drivable from
/// tests without any channel plumbing.
pub struct Engine {
    model: Arc<TransformerLM>,
    cfg: EngineConfig,
    pool: KvPool,
    seqs: Vec<Sequence>,
    /// Published prompt pages, keyed by token prefix — what shared-prefix
    /// admission matches against. Flushed back to the pool at full drain.
    prefix: PrefixIndex,
    /// Recycled kernel/decode buffers, kept across steps so the decode
    /// loop stops paying per-call `transpose()`/`zeros` allocations.
    ws: Workspace,
    telemetry: Arc<Mutex<EngineTelemetry>>,
    /// Process-unique id carried in this engine's trace event args.
    trace_id: u64,
}

impl Engine {
    pub fn new(model: Arc<TransformerLM>, cfg: EngineConfig) -> Engine {
        let cap = model.cfg.seq_len;
        let page_size = if cfg.page_size == 0 { cap } else { cfg.page_size.min(cap) };
        let per_seq = cap.div_ceil(page_size);
        // The arena must hold at least one full sequence: with less, a
        // long-but-admissible request could never be admitted and the
        // queue would wedge behind it forever.
        let kv_pages =
            if cfg.kv_pages == 0 { cfg.slots * per_seq } else { cfg.kv_pages.max(per_seq) };
        let pool = KvPool::with_pages(&model.cfg, cfg.slots, page_size, kv_pages);
        let telemetry = Arc::new(Mutex::new(EngineTelemetry {
            slots: cfg.slots,
            page_size,
            total_pages: kv_pages,
            kv_bytes: pool.memory_bytes(),
            ..Default::default()
        }));
        Engine {
            model,
            cfg,
            pool,
            seqs: Vec::new(),
            prefix: PrefixIndex::with_cap(page_size, cfg.prefix_cap),
            ws: Workspace::new(),
            telemetry,
            trace_id: NEXT_ENGINE_ID.fetch_add(1, Ordering::Relaxed),
        }
    }

    /// Shared handle to the telemetry (updated once per step).
    pub fn telemetry(&self) -> Arc<Mutex<EngineTelemetry>> {
        Arc::clone(&self.telemetry)
    }

    /// No resident sequences.
    pub fn is_idle(&self) -> bool {
        self.seqs.is_empty()
    }

    /// Slots currently held by sequences.
    pub fn occupied_slots(&self) -> usize {
        self.pool.occupied()
    }

    /// Pull queued requests into free slots. Requests that can never
    /// generate — empty prompts, zero budget, or prompts that already fill
    /// (or exceed) the whole KV capacity — are answered immediately with
    /// no slot and no prefill compute, even while the arena is full, so a
    /// rejection never waits behind resident decodes. A joiner is admitted
    /// only when, besides a free slot, its worst-case page need
    /// (`ceil(min(prompt + gen − 1, seq_len) / page_size)` — prompt pages
    /// plus decode headroom, with `gen` its own budget or the server
    /// default) fits in the arena's unreserved pages; the reservation
    /// guarantees every resident can grow to retirement, so admission can
    /// never deadlock mid-generation.
    ///
    /// Returns the admission counts for the caller to fold into the
    /// telemetry under one end-of-step lock (no per-request locking).
    fn admit(&mut self, queue: &mut Batcher, events: &mut Vec<SeqEvent>) -> StepCounts {
        let cap = self.model.cfg.seq_len;
        let gen = self.cfg.gen_tokens;
        let mut counts = StepCounts::default();
        let slot_free = queue
            .take_where(|r| r.prefill_len() >= cap || r.prompt.is_empty() || r.budget(gen) == 0);
        for req in slot_free {
            // prompt > cap is the rejection (`Truncated`); an empty prompt
            // or zero budget matches scalar `generate` (no logits to
            // decode from / nothing asked for — an empty completion); a
            // prefill stream that exactly fills the capacity had generation
            // stopped by memory, not by its budget. (For a preempted
            // requeue the stream is prompt + generated-so-far, and the
            // saved tokens are the answer — identical to what the resident
            // run would have capacity-stopped with.)
            let status = if req.prompt.len() > cap {
                counts.truncated += 1;
                ResponseStatus::Truncated
            } else if req.prompt.is_empty() || req.budget(gen) == 0 {
                ResponseStatus::Complete
            } else {
                counts.capacity_stopped += 1;
                ResponseStatus::CapacityStopped
            };
            trace::instant_args(
                "request_retired",
                &[("id", req.id as f64), ("engine", self.trace_id as f64)],
            );
            events.push(SeqEvent::Finished(FinishedSeq {
                id: req.id,
                tokens: req.resume.tokens,
                status,
                priority: req.priority,
                enqueued: req.enqueued,
                queue_wait: req
                    .resume
                    .admitted
                    .unwrap_or_else(Instant::now)
                    .saturating_duration_since(req.enqueued),
                first_token_latency: req
                    .resume
                    .first_token_at
                    .map(|t| t.saturating_duration_since(req.enqueued)),
            }));
        }
        // Worst-case KV positions a joiner can ever write: its prompt plus
        // budget-1 decoded tokens (the final sampled token is returned but
        // never fed back), clamped to capacity — per-request budgets shrink
        // the reservation, so short-budget requests admit alongside bigger
        // ones. Reserving exactly this keeps admission deadlock-free with
        // zero stranded pages. (The `.max(1)` only guards the arithmetic:
        // zero-budget requests were all answered slot-free above, so this
        // is never reached with a resolved budget of 0.)
        // (The formula also covers preempted requeues unchanged: their
        // budget is pinned to the value resolved at first admission, and
        // `prompt + budget` counts the resumed tokens exactly once whether
        // they arrive via prefill or decode.)
        let worst_case = |r: &Request| (r.prompt.len() + r.budget(gen).max(1) - 1).min(cap);
        let ps = self.pool.page_size();
        loop {
            if self.pool.available() == 0 {
                // Slot pressure: every slot is resident. A strictly
                // higher-tier queued request may still get in by evicting
                // a lower-tier victim (which frees its slot and pages).
                if self.cfg.preemption && self.preempt_for(queue, &mut counts) {
                    continue;
                }
                break;
            }
            let pool = &self.pool;
            let prefix = &self.prefix;
            // Owned pages a joiner must reserve: its worst case minus the
            // leading pages the prefix index already holds, plus one spare
            // when the whole prompt is covered (the last prompt token is
            // always recomputed for its logits, and that write lands inside
            // the last shared page — a guaranteed copy-on-write fork).
            let need_owned = |r: &Request| {
                let total = pool.pages_for(worst_case(r));
                if !r.share_prefix {
                    return total;
                }
                let n_shared = prefix.match_prefix(&r.prompt).len();
                let fork = n_shared > 0 && n_shared * ps == r.prompt.len();
                total - n_shared + fork as usize
            };
            let fits = |r: &Request| pool.can_admit(need_owned(r));
            let Some(req) = queue.pop_where(self.cfg.admission, fits) else {
                // Page pressure: published pages no sequence maps are the
                // reclaimable slack — evict one (longest prefix first) and
                // retry. Without queued work there is nothing to retry for,
                // and the index is left alone for future joiners.
                if queue.len() > 0 {
                    if let Some(page) = self.prefix.evict_unreferenced() {
                        self.pool.reclaim_shared(page);
                        continue;
                    }
                    // Page pressure with nothing left to reclaim from the
                    // index: a strictly higher-tier head may preempt a
                    // lower-tier resident for its pages.
                    if self.cfg.preemption && self.preempt_for(queue, &mut counts) {
                        continue;
                    }
                }
                break;
            };
            // Recompute the match for the popped request — nothing mutated
            // the index since the predicate ran, so this is the same match
            // the reservation was sized for. The commitment also stamps
            // the matched entries' LRU recency (the probe above did not).
            let matched = if req.share_prefix {
                self.prefix.match_and_touch(&req.prompt)
            } else {
                Vec::new()
            };
            let n_shared = matched.len();
            let shared_len = n_shared * ps;
            let fork = n_shared > 0 && shared_len == req.prompt.len();
            let need = self.pool.pages_for(worst_case(&req)) - n_shared + fork as usize;
            let slot = self.pool.acquire(need).expect("admission checked slot and pages");
            for page in matched {
                self.pool.attach_shared(slot, page);
            }
            // Fast-forward past the prefix the shared pages already hold.
            // The last prompt token is never skipped: its forward pass
            // produces the logits the first decode samples from.
            let resume = shared_len.min(req.prompt.len() - 1);
            self.pool.resume_at(slot, resume);
            counts.joins += 1;
            counts.prefill_tokens_saved += resume;
            counts.shared_pages += n_shared;
            if req.resume.preempted {
                // Everything past the shared-prefix resume point is
                // recompute the preemption caused: the original prompt
                // tail plus every token the victim had already generated.
                counts.victim_recompute_tokens += req.prefill_len() - resume;
                trace::instant_args(
                    "readmit_recompute",
                    &[("id", req.id as f64), ("engine", self.trace_id as f64)],
                );
            }
            trace::instant_args(
                "request_admitted",
                &[("id", req.id as f64), ("engine", self.trace_id as f64)],
            );
            let mut s = Sequence::new(req, slot, self.model.cfg.vocab, gen);
            s.next_prefill = resume;
            // The mapped pages are already in the index; the publish cursor
            // starts past them.
            s.published = n_shared;
            self.seqs.push(s);
        }
        counts
    }

    /// Evict one resident sequence to relieve slot or page pressure, but
    /// only when the queue's next pick STRICTLY outranks a resident by
    /// base tier — aging credit is deliberately excluded, so two same-tier
    /// requests can never preempt each other back and forth (no thrash).
    /// The victim with the lowest base tier (ties: least compute sunk,
    /// then lowest id — all deterministic) releases its slot and every
    /// page it holds back to the pool and re-queues at the FRONT with its
    /// generated tokens saved; readmission re-prefills them (see
    /// [`sched::ResumeState`]). Pages the victim *published* to the prefix
    /// index are owned by the index, not the slot, so they survive the
    /// release and stay mappable by other requests. Returns whether a
    /// victim was evicted; an eviction counts as a `leave` (the slot was
    /// vacated) and the later readmission as a fresh `join`, so
    /// `joins == leaves` still holds exactly at drain.
    fn preempt_for(&mut self, queue: &mut Batcher, counts: &mut StepCounts) -> bool {
        let Some(head) = queue.peek(self.cfg.admission) else {
            return false;
        };
        let head_rank = head.priority.rank();
        let victim = self
            .seqs
            .iter()
            .enumerate()
            .filter(|(_, s)| s.priority.rank() < head_rank)
            .min_by_key(|&(_, s)| (s.priority.rank(), s.next_prefill + s.out.len(), s.id))
            .map(|(i, _)| i);
        let Some(idx) = victim else {
            return false;
        };
        let s = self.seqs.remove(idx);
        trace::instant_args("preempt", &[("id", s.id as f64), ("engine", self.trace_id as f64)]);
        self.pool.release(s.slot);
        let req = s.into_request();
        trace::instant_args("requeue", &[("id", req.id as f64), ("engine", self.trace_id as f64)]);
        queue.reinsert(req);
        counts.preemptions += 1;
        counts.leaves += 1;
        true
    }

    /// SLO-aware load shedding: estimate the engine steps until the queue
    /// would drain to its first token (resident prefill chunks + decode
    /// steps, plus the queued requests' own, spread across the slots) and,
    /// while that estimate exceeds `slo_first_token_steps`, drop the
    /// newest lowest-tier queued request with [`ResponseStatus::Shed`] —
    /// admitted work keeps its SLO instead of the whole queue missing it.
    /// Deterministic: the predictor reads only logical quantities (queue
    /// contents, resident progress), never wall time.
    fn shed_over_slo(
        &mut self,
        queue: &mut Batcher,
        events: &mut Vec<SeqEvent>,
        counts: &mut StepCounts,
    ) {
        let slo = self.cfg.slo_first_token_steps;
        let gen = self.cfg.gen_tokens;
        let chunk = self.cfg.prefill_chunk.max(1);
        let slots = self.cfg.slots.max(1);
        while queue.len() > 0 {
            let resident: usize = self
                .seqs
                .iter()
                .map(|s| {
                    let prefill = s.prompt.len().saturating_sub(s.next_prefill);
                    prefill.div_ceil(chunk) + s.budget.saturating_sub(s.out.len())
                })
                .sum();
            let queued: usize = queue
                .iter()
                .map(|r| r.prefill_len().div_ceil(chunk) + r.budget(gen).max(1))
                .sum();
            if (resident + queued) / slots <= slo {
                break;
            }
            let Some(req) = queue.shed_pop() else {
                break;
            };
            trace::instant_args("shed", &[("id", req.id as f64), ("engine", self.trace_id as f64)]);
            trace::instant_args(
                "request_retired",
                &[("id", req.id as f64), ("engine", self.trace_id as f64)],
            );
            counts.shed += 1;
            events.push(SeqEvent::Finished(FinishedSeq {
                id: req.id,
                // A shed request that had run before preemption returns
                // its partial output; a never-admitted one returns none.
                tokens: req.resume.tokens,
                status: ResponseStatus::Shed,
                priority: req.priority,
                enqueued: req.enqueued,
                queue_wait: req
                    .resume
                    .admitted
                    .unwrap_or_else(Instant::now)
                    .saturating_duration_since(req.enqueued),
                first_token_latency: req
                    .resume
                    .first_token_at
                    .map(|t| t.saturating_duration_since(req.enqueued)),
            }));
        }
    }

    /// One lockstep model call over the given resident sequences (indices
    /// into `self.seqs`), feeding `tokens[i]` to sequence `idxs[i]` and
    /// storing each sequence's fresh logits row. Each participating slot
    /// gets its next KV page attached first if the position being written
    /// has no backing page yet (acquire-on-demand; covered by the
    /// admission-time reservation, so the free list cannot run dry). A
    /// write landing inside a *shared* page copies it into owned storage
    /// first (copy-on-write — also covered by the reservation), so shared
    /// prefix pages are never mutated.
    fn batch_decode(&mut self, idxs: &[usize], tokens: &[usize], counts: &mut StepCounts) {
        let ps = self.pool.page_size();
        let slots: Vec<usize> = idxs.iter().map(|&i| self.seqs[i].slot).collect();
        for &slot in &slots {
            let cache = self.pool.cache(slot);
            let page_idx = cache.len / ps;
            if page_idx < cache.pages_held() && cache.page_is_shared(page_idx) {
                self.pool.fork_page(slot, page_idx);
                counts.cow_forks += 1;
            }
            self.pool.ensure_page(slot);
        }
        let mut caches = self.pool.caches_mut(&slots);
        // The engine-owned workspace persists across steps, so the batched
        // kernels' Xᵀ panels and outputs recycle instead of reallocating.
        let logits = self.model.decode_step_batch_ws(tokens, &mut caches, &mut self.ws);
        drop(caches);
        for (r, &i) in idxs.iter().enumerate() {
            let s = &mut self.seqs[i];
            s.logits.clear();
            s.logits.extend_from_slice(logits.row(r));
        }
        self.ws.recycle(logits);
    }

    /// Fold one worked step into the telemetry (single lock).
    fn record_step(
        &self,
        queue: &Batcher,
        decode_width: usize,
        counts: StepCounts,
        phases: PhaseTimes,
    ) {
        let held = self.pool.pages_held();
        trace::counter("queue_depth", queue.len() as f64);
        trace::counter("kv_pages_in_use", held as f64);
        let mut t = self.telemetry.lock().unwrap();
        t.steps += 1;
        t.joins += counts.joins;
        t.truncated += counts.truncated;
        t.capacity_stopped += counts.capacity_stopped;
        t.leaves += counts.leaves;
        t.preemptions += counts.preemptions;
        t.shed += counts.shed;
        t.victim_recompute_tokens += counts.victim_recompute_tokens;
        t.slo_hits += counts.slo_hits;
        t.decode_batch.push(decode_width as f64);
        t.occupancy.push(self.pool.occupied() as f64 / self.pool.slots() as f64);
        t.queue_depth.push(queue.len() as f64);
        t.pages_in_use.push(held as f64);
        t.page_occupancy.push(held as f64 / self.pool.pages_total() as f64);
        t.pages_in_use_now = held;
        t.ws_buffer_allocs = self.ws.alloc_count();
        t.prefill_tokens_saved += counts.prefill_tokens_saved;
        t.shared_pages += counts.shared_pages;
        t.cow_forks += counts.cow_forks;
        t.prefix_evictions_cap += counts.prefix_evictions_cap;
        t.time_admit_s += phases.admit;
        t.time_prefill_s += phases.prefill;
        t.time_decode_s += phases.decode;
        t.time_retire_s += phases.retire;
        t.time_step_s += phases.step;
        t.trim();
    }

    /// One engine step: admit → chunked prefill → lockstep decode →
    /// retire → same-step backfill. Returns the step's events (streamed
    /// tokens and finished sequences). A step with nothing resident and
    /// nothing answerable returns immediately and records no telemetry
    /// (an idle poll); slot-free answers alone — rejections included —
    /// count as a worked step and sample telemetry, so rejection-only
    /// traffic still produces meaningful `SERVE_*.json` summaries.
    pub fn step(&mut self, queue: &mut Batcher) -> Vec<SeqEvent> {
        let step_start = Instant::now();
        let _step = trace::span("engine_step");
        // Advance the queue's logical clock exactly once per step — the
        // deterministic time base for aging credit and the first-token
        // SLO. (Idle polls tick too; with nothing queued there is nothing
        // aging, so that is harmless.)
        queue.tick();
        let mut events = Vec::new();
        let mut counts = {
            let _admit = trace::span("admit");
            let mut c = self.admit(queue, &mut events);
            if self.cfg.shed_policy == ShedPolicy::LowestPriority
                && self.cfg.slo_first_token_steps > 0
            {
                self.shed_over_slo(queue, &mut events, &mut c);
            }
            c
        };
        let mut phases =
            PhaseTimes { admit: step_start.elapsed().as_secs_f64(), ..Default::default() };
        if self.seqs.is_empty() {
            // Nothing resident: only slot-free answers may have happened
            // (a join would have left a resident sequence).
            if !events.is_empty() {
                phases.step = step_start.elapsed().as_secs_f64();
                self.record_step(queue, 0, counts, phases);
            }
            #[cfg(debug_assertions)]
            self.pool.audit();
            return events;
        }

        // ── chunked prefill (batched across joiners) ──
        let phase_start = Instant::now();
        for _ in 0..self.cfg.prefill_chunk.max(1) {
            let pidx: Vec<usize> =
                (0..self.seqs.len()).filter(|&i| self.seqs[i].prefilling()).collect();
            if pidx.is_empty() {
                break;
            }
            let _chunk = trace::span_args("prefill_chunk", &[("width", pidx.len() as f64)]);
            let tokens: Vec<usize> = pidx
                .iter()
                .map(|&i| {
                    let s = &self.seqs[i];
                    s.prompt[s.next_prefill]
                })
                .collect();
            self.batch_decode(&pidx, &tokens, &mut counts);
            for &i in &pidx {
                self.seqs[i].next_prefill += 1;
            }
        }

        // ── publish freshly filled prompt pages to the prefix index ──
        // A page is publishable once every one of its positions holds a
        // *prompt* row (`(cursor+1)·ps ≤ min(prompt, cache.len)`), which is
        // also why a publisher can never write into a page it published:
        // its next write position is at or past `cache.len`. Occupied index
        // keys (same prefix already published, or a hash collision) and
        // pages this sequence itself mapped as shared are skipped. In the
        // degenerate whole-sequence layout no admissible prompt ever fills
        // a page, so sharing self-disables.
        let ps = self.pool.page_size();
        for i in 0..self.seqs.len() {
            if !self.seqs[i].share_prefix {
                continue;
            }
            loop {
                let s = &self.seqs[i];
                let (slot, cursor) = (s.slot, s.published);
                let end = (cursor + 1) * ps;
                if end > s.prompt.len().min(self.pool.cache(slot).len) {
                    break;
                }
                if !self.pool.cache(slot).page_is_shared(cursor)
                    && !self.prefix.contains(&s.prompt[..end])
                {
                    let prefix_tokens = s.prompt[..end].to_vec();
                    let page = self.pool.share_page(slot, cursor);
                    // A publish that overflows the capacity cap LRU-evicts
                    // stale unreferenced entries; their pages go straight
                    // back to the pool's free list.
                    for evicted in self.prefix.insert(&prefix_tokens, page) {
                        self.pool.reclaim_shared(evicted);
                        counts.prefix_evictions_cap += 1;
                    }
                }
                self.seqs[i].published += 1;
            }
        }
        phases.prefill = phase_start.elapsed().as_secs_f64();

        // ── lockstep decode over prefilled sequences with room to emit ──
        let phase_start = Instant::now();
        let didx: Vec<usize> = (0..self.seqs.len())
            .filter(|&i| {
                let s = &self.seqs[i];
                !s.prefilling() && s.out.len() < s.budget && self.pool.cache(s.slot).remaining() > 0
            })
            .collect();
        if !didx.is_empty() {
            let _decode = trace::span_args("decode_batch", &[("width", didx.len() as f64)]);
            let now = Instant::now();
            let mut cont = Vec::with_capacity(didx.len());
            let mut cont_tokens = Vec::with_capacity(didx.len());
            for &i in &didx {
                let s = &mut self.seqs[i];
                let t = argmax(&s.logits);
                s.out.push(t);
                let first = s.out.len() == 1;
                if first {
                    s.first_token_at = Some(now);
                    // Goodput: the first token landed within the SLO's
                    // logical-step window (or no SLO is configured). A
                    // preempted-with-output victim never re-enters here —
                    // its pre-seeded `out` keeps `first` false — so each
                    // request is counted at most once.
                    let slo = self.cfg.slo_first_token_steps as u64;
                    if slo == 0 || queue.clock().saturating_sub(s.arrived_tick) <= slo {
                        counts.slo_hits += 1;
                    }
                    trace::instant_args(
                        "request_first_token",
                        &[("id", s.id as f64), ("engine", self.trace_id as f64)],
                    );
                }
                events.push(SeqEvent::Token { id: s.id, token: t, first });
                if s.out.len() < s.budget && !s.stopped_at_token() {
                    cont.push(i);
                    cont_tokens.push(t);
                }
            }
            // Decode the emitted token only for sequences that still need
            // the next logits. A sequence that just spent its budget (or
            // emitted one of its stop tokens) retires below and its cache
            // is recycled, so the extra forward pass scalar `generate`
            // performs there would be discarded — skipping it cannot
            // change any emitted token.
            if !cont.is_empty() {
                self.batch_decode(&cont, &cont_tokens, &mut counts);
            }
        }
        phases.decode = phase_start.elapsed().as_secs_f64();

        // ── retire finished sequences, releasing their slots (and every
        // page they held, back to the free list) ──
        let phase_start = Instant::now();
        {
            let _retire = trace::span("retire");
            let seqs = std::mem::take(&mut self.seqs);
            for s in seqs {
                let budget_met = s.out.len() >= s.budget;
                let stopped = s.stopped_at_token();
                let capacity_hit = self.pool.cache(s.slot).remaining() == 0;
                if !s.prefilling() && (budget_met || stopped || capacity_hit) {
                    self.pool.release(s.slot);
                    counts.leaves += 1;
                    // A stop token is the most specific outcome (it names
                    // the token that ended generation, even when the budget
                    // ran out on the same step); a sequence that filled its
                    // KV capacity before reaching the budget was truncated
                    // by memory, not completed.
                    let status = if stopped {
                        ResponseStatus::StoppedAtToken
                    } else if budget_met {
                        ResponseStatus::Complete
                    } else {
                        counts.capacity_stopped += 1;
                        ResponseStatus::CapacityStopped
                    };
                    trace::instant_args(
                        "request_retired",
                        &[("id", s.id as f64), ("engine", self.trace_id as f64)],
                    );
                    events.push(SeqEvent::Finished(FinishedSeq {
                        id: s.id,
                        tokens: s.out,
                        status,
                        priority: s.priority,
                        enqueued: s.enqueued,
                        queue_wait: s.admitted.saturating_duration_since(s.enqueued),
                        first_token_latency: s.first_token_at.map(|t| t - s.enqueued),
                    }));
                } else {
                    self.seqs.push(s);
                }
            }
        }
        phases.retire = phase_start.elapsed().as_secs_f64();

        // ── same-step backfill: freed slots go straight to the queue ──
        let phase_start = Instant::now();
        {
            let _backfill = trace::span("backfill");
            counts.absorb(self.admit(queue, &mut events));
        }
        phases.admit += phase_start.elapsed().as_secs_f64();

        // ── drained: flush the prefix index back to the pool ──
        // With no residents and no queued work every published page is
        // mapped by the index alone, so the flush reclaims them all and
        // the pages-held leak check stays exact between workloads.
        if self.seqs.is_empty() && queue.len() == 0 {
            for page in self.prefix.drain_pages() {
                self.pool.reclaim_shared(page);
            }
        }

        // Debug builds re-prove the pool conservation invariants after
        // every step (and hence after every drain, thanks to the flush
        // above): `free + Σ owned + shared == total`, `owned ≤ reserved`
        // per slot. Compiled out of release builds.
        #[cfg(debug_assertions)]
        self.pool.audit();

        phases.step = step_start.elapsed().as_secs_f64();
        self.record_step(queue, didx.len(), counts, phases);
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn tiny() -> Arc<TransformerLM> {
        Arc::new(TransformerLM::init(&ModelConfig::preset("tiny").unwrap(), 5))
    }

    fn req(id: u64, prompt: Vec<usize>) -> Request {
        Request::new(id, prompt)
    }

    /// Drive the engine until `n` sequences finish; panics if it stalls.
    fn drain(engine: &mut Engine, queue: &mut Batcher, n: usize) -> Vec<FinishedSeq> {
        let mut done = Vec::new();
        for _ in 0..10_000 {
            for ev in engine.step(queue) {
                if let SeqEvent::Finished(f) = ev {
                    done.push(f);
                }
            }
            if done.len() >= n {
                return done;
            }
        }
        panic!("engine stalled: {} of {n} finished", done.len());
    }

    #[test]
    fn oversized_prompt_is_rejected_not_truncated() {
        let m = tiny();
        let cap = m.cfg.seq_len;
        let mut e = Engine::new(Arc::clone(&m), EngineConfig { slots: 2, ..Default::default() });
        let mut q = Batcher::default();
        q.push(req(0, vec![1; cap + 3]));
        q.push(req(1, vec![1, 2]));
        let done = drain(&mut e, &mut q, 2);
        let over = done.iter().find(|f| f.id == 0).unwrap();
        assert_eq!(over.status, ResponseStatus::Truncated);
        assert!(over.tokens.is_empty(), "rejected request must not generate");
        let ok = done.iter().find(|f| f.id == 1).unwrap();
        assert_eq!(ok.status, ResponseStatus::Complete);
        assert_eq!(ok.tokens.len(), 16);
        assert_eq!(e.telemetry().lock().unwrap().truncated, 1);
    }

    #[test]
    fn prompt_at_exact_capacity_is_capacity_stopped() {
        let m = tiny();
        let cap = m.cfg.seq_len;
        let mut e = Engine::new(Arc::clone(&m), EngineConfig::default());
        let mut q = Batcher::default();
        q.push(req(0, (0..cap).map(|i| i % 16).collect()));
        let done = drain(&mut e, &mut q, 1);
        // No KV room left to generate: stopped by memory, not by budget —
        // and distinguishable as such.
        assert_eq!(done[0].status, ResponseStatus::CapacityStopped);
        assert!(done[0].tokens.is_empty(), "no KV room left to generate");
        let t = e.telemetry().lock().unwrap().clone();
        assert_eq!(t.joins, 0, "a prompt that fills the cache must not burn a slot or prefill");
        assert_eq!(t.capacity_stopped, 1);
    }

    #[test]
    fn rejection_only_traffic_still_counts_steps_and_samples() {
        // Regression: slot-free answers used to return before the
        // telemetry block, so a run of nothing but rejections emitted a
        // SERVE json with steps == 0 and empty summaries.
        let m = tiny();
        let cap = m.cfg.seq_len;
        let mut e = Engine::new(m, EngineConfig::default());
        let mut q = Batcher::default();
        q.push(req(0, vec![1; cap + 1]));
        q.push(req(1, vec![1; cap + 9]));
        let done = drain(&mut e, &mut q, 2);
        assert!(done.iter().all(|f| f.status == ResponseStatus::Truncated));
        let t = e.telemetry().lock().unwrap().clone();
        assert_eq!(t.truncated, 2);
        assert!(t.steps > 0, "rejections are worked steps");
        assert_eq!(t.steps, t.occupancy.len(), "every worked step samples telemetry");
        assert_eq!(t.steps, t.queue_depth.len());
        assert_eq!(t.steps, t.page_occupancy.len());
        // An idle poll afterwards still records nothing.
        let none = e.step(&mut q);
        assert!(none.is_empty());
        assert_eq!(e.telemetry().lock().unwrap().steps, t.steps);
    }

    #[test]
    fn capacity_stop_mid_generation_is_flagged() {
        // Budget larger than the KV room: generation must stop at
        // capacity and say so. generate() under the same budget stops at
        // the same place, so tokens still match the scalar reference.
        let m = tiny();
        let cap = m.cfg.seq_len;
        let prompt: Vec<usize> = (0..cap - 3).map(|i| i % 16).collect();
        let cfg = EngineConfig { slots: 1, gen_tokens: 10, ..Default::default() };
        let mut e = Engine::new(Arc::clone(&m), cfg);
        let mut q = Batcher::default();
        q.push(req(0, prompt.clone()));
        let done = drain(&mut e, &mut q, 1);
        assert_eq!(done[0].status, ResponseStatus::CapacityStopped);
        assert_eq!(done[0].tokens.len(), 3, "exactly the remaining KV room");
        assert_eq!(done[0].tokens, crate::coordinator::serve::generate(&m, &prompt, 10));
        let t = e.telemetry().lock().unwrap().clone();
        assert_eq!(t.capacity_stopped, 1);
        assert_eq!(t.leaves, 1);
    }

    #[test]
    fn paged_engine_conserves_pages_and_matches_outputs() {
        let m = tiny();
        let cfg = EngineConfig {
            slots: 3,
            gen_tokens: 4,
            page_size: 8,
            kv_pages: 12,
            ..Default::default()
        };
        let mut e = Engine::new(Arc::clone(&m), cfg);
        let mut q = Batcher::default();
        let prompts: Vec<Vec<usize>> =
            (0..7).map(|i| (0..(2 + i * 3) % 21).map(|j| (i * 5 + j) % 16).collect()).collect();
        for (i, p) in prompts.iter().enumerate() {
            q.push(req(i as u64, p.clone()));
        }
        let done = drain(&mut e, &mut q, prompts.len());
        for f in &done {
            let want = crate::coordinator::serve::generate(&m, &prompts[f.id as usize], 4);
            assert_eq!(f.tokens, want, "paged engine diverged on request {}", f.id);
        }
        let t = e.telemetry().lock().unwrap().clone();
        assert_eq!(t.page_size, 8);
        assert_eq!(t.total_pages, 12);
        assert_eq!(t.pages_in_use_now, 0, "pages leaked after drain");
        assert!(t.pages_in_use.iter().all(|&p| p <= 12.0));
        assert!(t.page_occupancy.iter().all(|&o| (0.0..=1.0).contains(&o)));
        assert!(t.page_occupancy.iter().any(|&o| o > 0.0), "pages were used");
    }

    #[test]
    fn admission_waits_for_page_headroom_not_just_slots() {
        // Arena of exactly one full sequence's pages: the second request
        // must wait for the first to retire even though a slot is free,
        // and both must still finish (no deadlock, no starvation).
        let m = tiny();
        let cap = m.cfg.seq_len; // 64 → per-seq worst case 4 pages of 16
        let cfg = EngineConfig {
            slots: 2,
            gen_tokens: 4,
            page_size: 16,
            kv_pages: 4,
            ..Default::default()
        };
        let mut e = Engine::new(Arc::clone(&m), cfg);
        let mut q = Batcher::default();
        q.push(req(0, (0..cap - 8).map(|i| i % 16).collect())); // reserves all 4 pages
        q.push(req(1, vec![1, 2, 3]));
        let done = drain(&mut e, &mut q, 2);
        assert_eq!(done.len(), 2);
        let t = e.telemetry().lock().unwrap().clone();
        assert_eq!(t.joins, 2);
        assert_eq!(t.leaves, 2);
        assert!(
            t.occupancy.iter().all(|&o| o <= 0.5),
            "page headroom must keep residency to one sequence: {:?}",
            t.occupancy
        );
        assert_eq!(t.pages_in_use_now, 0);
    }

    #[test]
    fn rejection_bypasses_a_full_arena() {
        // One slot held by a long-running sequence: an oversized prompt
        // must still be rejected immediately, not after the resident
        // sequence drains.
        let m = tiny();
        let cap = m.cfg.seq_len;
        let cfg = EngineConfig { slots: 1, gen_tokens: 40, ..Default::default() };
        let mut e = Engine::new(m, cfg);
        let mut q = Batcher::default();
        q.push(req(0, vec![1, 2]));
        let _ = e.step(&mut q); // resident sequence occupies the only slot
        assert_eq!(e.occupied_slots(), 1);
        q.push(req(1, vec![1; cap + 2]));
        let events = e.step(&mut q);
        let rejected = events.iter().any(|ev| {
            matches!(ev, SeqEvent::Finished(f)
                if f.id == 1 && f.status == ResponseStatus::Truncated)
        });
        assert!(rejected, "rejection must not wait behind the full arena");
    }

    #[test]
    fn telemetry_sample_vectors_stay_bounded() {
        let mut t = EngineTelemetry::default();
        for i in 0..(2 * TELEMETRY_WINDOW + 5) {
            t.decode_batch.push(i as f64);
            t.occupancy.push(0.5);
            t.queue_depth.push(0.0);
            t.trim();
        }
        assert!(t.decode_batch.len() < 2 * TELEMETRY_WINDOW);
        assert!(t.decode_batch.len() >= TELEMETRY_WINDOW, "keeps at least a full window");
        // The newest samples survive trimming.
        assert_eq!(*t.decode_batch.last().unwrap(), (2 * TELEMETRY_WINDOW + 4) as f64);
    }

    #[test]
    fn empty_prompt_and_zero_budget_complete_without_slots() {
        let m = tiny();
        let mut e = Engine::new(Arc::clone(&m), EngineConfig { slots: 1, ..Default::default() });
        let mut q = Batcher::default();
        q.push(req(0, vec![]));
        let done = drain(&mut e, &mut q, 1);
        assert!(done[0].tokens.is_empty());
        let t = e.telemetry().lock().unwrap().clone();
        assert_eq!(t.joins, 0, "empty prompt must not consume a slot");

        let mut e0 = Engine::new(m, EngineConfig { gen_tokens: 0, slots: 1, ..Default::default() });
        let mut q0 = Batcher::default();
        q0.push(req(1, vec![1, 2, 3]));
        let done = drain(&mut e0, &mut q0, 1);
        assert!(done[0].tokens.is_empty());
    }

    #[test]
    fn per_request_budget_overrides_server_default() {
        let m = tiny();
        let cfg = EngineConfig { slots: 3, gen_tokens: 8, ..Default::default() };
        let mut e = Engine::new(Arc::clone(&m), cfg);
        let mut q = Batcher::default();
        q.push(req(0, vec![1, 2, 3])); // server default: 8 tokens
        q.push(req(1, vec![1, 2, 3]).with_budget(2));
        q.push(req(2, vec![4, 5]).with_budget(0)); // answered slot-free
        let done = drain(&mut e, &mut q, 3);
        let by_id = |id: u64| done.iter().find(|f| f.id == id).unwrap();
        assert_eq!(by_id(0).tokens, crate::coordinator::serve::generate(&m, &[1, 2, 3], 8));
        assert_eq!(by_id(1).tokens, crate::coordinator::serve::generate(&m, &[1, 2, 3], 2));
        assert_eq!(by_id(1).tokens.len(), 2, "per-request budget must cap generation");
        assert!(by_id(2).tokens.is_empty());
        assert_eq!(by_id(2).status, ResponseStatus::Complete);
        let t = e.telemetry().lock().unwrap().clone();
        assert_eq!(t.joins, 2, "a zero-budget request must not take a slot");
    }

    #[test]
    fn short_budget_requests_reserve_fewer_pages() {
        // The PR-4 follow-up this enables: at page_size 16 over a 64-token
        // capacity, a default-budget joiner (len 40, gen 16 → worst case 55
        // positions) reserves 4 pages. A 5-page arena then has one page of
        // headroom — enough for a short-budget request (len 3, gen 2 →
        // worst case 4 positions → 1 page) to run CONCURRENTLY, where the
        // same request under the server-wide default (worst case 18 → 2
        // pages) would have to wait for the big one to retire.
        let m = tiny();
        assert_eq!(m.cfg.seq_len, 64, "sizing below assumes the tiny preset");
        let cfg = EngineConfig {
            slots: 2,
            gen_tokens: 16,
            page_size: 16,
            kv_pages: 5,
            ..Default::default()
        };
        let big: Vec<usize> = (0..40).map(|i| i % 16).collect();
        let run = |budget: Option<usize>| {
            let mut e = Engine::new(Arc::clone(&m), cfg);
            let mut q = Batcher::default();
            q.push(req(0, big.clone()));
            let mut small = req(1, vec![1, 2, 3]);
            small.gen_tokens = budget;
            q.push(small);
            let done = drain(&mut e, &mut q, 2);
            let t = e.telemetry().lock().unwrap().clone();
            (done, t)
        };
        let (done, t) = run(Some(2));
        assert_eq!(done.iter().find(|f| f.id == 1).unwrap().tokens.len(), 2);
        let peak = t.occupancy.iter().cloned().fold(0.0f64, f64::max);
        assert_eq!(peak, 1.0, "short-budget request must fit alongside the big one: {t:?}");
        assert_eq!(t.pages_in_use_now, 0, "pages leaked");
        let (_, t_default) = run(None);
        let peak_default = t_default.occupancy.iter().cloned().fold(0.0f64, f64::max);
        assert!(
            peak_default <= 0.5,
            "default-budget reservation must serialize the pair: {:?}",
            t_default.occupancy
        );
    }

    #[test]
    fn decode_workspace_stops_allocating_across_steps() {
        // The workspace-reuse contract at the engine level: once the
        // per-step shapes have been seen, further steps take every buffer
        // from the pool (ws_buffer_allocs goes flat).
        let m = tiny();
        let cfg = EngineConfig { slots: 2, gen_tokens: 24, ..Default::default() };
        let mut e = Engine::new(m, cfg);
        let mut q = Batcher::default();
        q.push(req(0, vec![1, 2, 3]));
        q.push(req(1, vec![4, 5, 6]));
        for _ in 0..6 {
            e.step(&mut q);
        }
        let warm = e.telemetry().lock().unwrap().ws_buffer_allocs;
        assert!(warm > 0, "first steps must populate the workspace");
        for _ in 0..10 {
            e.step(&mut q);
        }
        let later = e.telemetry().lock().unwrap().ws_buffer_allocs;
        assert_eq!(warm, later, "steady-state decode steps must not allocate");
    }

    #[test]
    fn retired_slot_backfills_same_step() {
        // One slot, two requests: the moment the first retires, the second
        // must be admitted in that same step (visible as occupancy == 1.0
        // on the retiring step's sample).
        let m = tiny();
        let cfg = EngineConfig { slots: 1, gen_tokens: 2, ..Default::default() };
        let mut e = Engine::new(m, cfg);
        let mut q = Batcher::default();
        q.push(req(0, vec![1, 2]));
        q.push(req(1, vec![3, 4]));
        let done = drain(&mut e, &mut q, 2);
        assert_eq!(done.len(), 2);
        let t = e.telemetry().lock().unwrap().clone();
        assert_eq!(t.joins, 2);
        assert_eq!(t.leaves, 2);
        // Every recorded step except the last must show a fully occupied
        // arena: the backfill happened inside the retiring step.
        let occ = &t.occupancy;
        assert!(occ[..occ.len() - 1].iter().all(|&o| o == 1.0), "{occ:?}");
    }

    #[test]
    fn decode_batch_never_exceeds_slots() {
        let m = tiny();
        let cfg = EngineConfig { slots: 3, gen_tokens: 4, ..Default::default() };
        let mut e = Engine::new(m, cfg);
        let mut q = Batcher::default();
        for i in 0..8 {
            q.push(req(i, vec![1 + i as usize % 5]));
        }
        let _ = drain(&mut e, &mut q, 8);
        let t = e.telemetry().lock().unwrap().clone();
        assert!(t.decode_batch.iter().all(|&b| b <= 3.0), "{:?}", t.decode_batch);
        assert_eq!(t.joins, 8);
        assert_eq!(t.leaves, 8);
    }

    #[test]
    fn shared_prefix_joiner_skips_prefill_and_matches_scalar() {
        // One slot forces serial residency: the donor prefills and
        // publishes its prompt pages, then the joiner (same 10-token head,
        // divergent tail) admits at backfill and maps the two fully
        // common pages instead of re-prefilling them.
        let m = tiny();
        let cfg = EngineConfig { slots: 1, gen_tokens: 4, page_size: 4, ..Default::default() };
        let head: Vec<usize> = (1..=10).collect();
        let donor: Vec<usize> = head.iter().copied().chain([11, 12]).collect();
        let joiner: Vec<usize> = head.iter().copied().chain([13, 14]).collect();
        let mut e = Engine::new(Arc::clone(&m), cfg);
        let mut q = Batcher::default();
        q.push(req(0, donor.clone()));
        q.push(req(1, joiner.clone()));
        let done = drain(&mut e, &mut q, 2);
        let by_id = |id: u64| done.iter().find(|f| f.id == id).unwrap();
        assert_eq!(by_id(0).tokens, crate::coordinator::serve::generate(&m, &donor, 4));
        assert_eq!(by_id(1).tokens, crate::coordinator::serve::generate(&m, &joiner, 4));
        let t = e.telemetry().lock().unwrap().clone();
        // Pages [0..4) and [4..8) are common and full; [8..12) diverges.
        assert_eq!(t.shared_pages, 2, "joiner must map the two common pages");
        assert_eq!(t.prefill_tokens_saved, 8, "8 head tokens never re-prefilled");
        assert_eq!(t.cow_forks, 0, "divergent tail needs no fork");
        assert_eq!(t.pages_in_use_now, 0, "shared pages leaked past drain");
    }

    #[test]
    fn identical_page_aligned_prompts_fork_before_the_last_token() {
        // The whole 8-token prompt is covered by shared pages, but the
        // last prompt token is always recomputed for its logits — that
        // write lands inside the final shared page and must copy it first.
        let m = tiny();
        let cfg = EngineConfig { slots: 1, gen_tokens: 3, page_size: 4, ..Default::default() };
        let prompt: Vec<usize> = (1..=8).collect();
        let mut e = Engine::new(Arc::clone(&m), cfg);
        let mut q = Batcher::default();
        q.push(req(0, prompt.clone()));
        q.push(req(1, prompt.clone()));
        let done = drain(&mut e, &mut q, 2);
        let want = crate::coordinator::serve::generate(&m, &prompt, 3);
        for f in &done {
            assert_eq!(f.tokens, want, "request {} diverged", f.id);
        }
        let t = e.telemetry().lock().unwrap().clone();
        assert_eq!(t.shared_pages, 2);
        assert_eq!(t.prefill_tokens_saved, 7, "all but the recomputed last token");
        assert_eq!(t.cow_forks, 1, "the recomputed token must fork the shared page");
        assert_eq!(t.pages_in_use_now, 0);
    }

    #[test]
    fn share_prefix_opt_out_disables_reuse_per_request() {
        let m = tiny();
        let cfg = EngineConfig { slots: 1, gen_tokens: 4, page_size: 4, ..Default::default() };
        let prompt: Vec<usize> = (1..=10).collect();
        let mut e = Engine::new(Arc::clone(&m), cfg);
        let mut q = Batcher::default();
        q.push(req(0, prompt.clone()));
        q.push(req(1, prompt.clone()).without_prefix_sharing());
        let done = drain(&mut e, &mut q, 2);
        let want = crate::coordinator::serve::generate(&m, &prompt, 4);
        for f in &done {
            assert_eq!(f.tokens, want);
        }
        let t = e.telemetry().lock().unwrap().clone();
        assert_eq!(t.shared_pages, 0, "opted-out request must not map shared pages");
        assert_eq!(t.prefill_tokens_saved, 0);
        assert_eq!(t.pages_in_use_now, 0);
    }

    #[test]
    fn prefix_cap_evicts_stale_entries_and_serves_identically() {
        // Three disjoint 8-token prompts through a cap-1 index at page
        // size 4: each sequence publishes two pages, so the previous
        // sequence's (by-then unreferenced) entries must be LRU-evicted
        // to honor the cap — visibly in the telemetry, invisibly in the
        // outputs.
        let m = tiny();
        let cfg = EngineConfig {
            slots: 1,
            gen_tokens: 3,
            page_size: 4,
            prefix_cap: 1,
            ..Default::default()
        };
        let mut e = Engine::new(Arc::clone(&m), cfg);
        let mut q = Batcher::default();
        let prompts: Vec<Vec<usize>> =
            (0..3).map(|i| (0..8).map(|j| (i * 7 + j + 1) % 16).collect()).collect();
        for (i, p) in prompts.iter().enumerate() {
            q.push(req(i as u64, p.clone()));
        }
        let done = drain(&mut e, &mut q, prompts.len());
        for f in &done {
            let want = crate::coordinator::serve::generate(&m, &prompts[f.id as usize], 3);
            assert_eq!(f.tokens, want, "capped engine diverged on request {}", f.id);
        }
        let t = e.telemetry().lock().unwrap().clone();
        assert!(t.prefix_evictions_cap > 0, "cap must have evicted: {t:?}");
        assert_eq!(t.pages_in_use_now, 0, "cap-evicted pages must return to the pool");

        // The same load through an unbounded index evicts nothing.
        let mut e0 = Engine::new(
            Arc::clone(&m),
            EngineConfig { prefix_cap: 0, ..cfg },
        );
        let mut q0 = Batcher::default();
        for (i, p) in prompts.iter().enumerate() {
            q0.push(req(i as u64, p.clone()));
        }
        let done0 = drain(&mut e0, &mut q0, prompts.len());
        for f in &done0 {
            let capped = done.iter().find(|g| g.id == f.id).unwrap();
            assert_eq!(f.tokens, capped.tokens, "cap changed request {}'s output", f.id);
        }
        assert_eq!(e0.telemetry().lock().unwrap().prefix_evictions_cap, 0);
    }

    #[test]
    fn stop_token_retires_with_stopped_status_and_truncated_output() {
        let m = tiny();
        let prompt = vec![1, 2, 3];
        let free = crate::coordinator::serve::generate(&m, &prompt, 16);
        let stop = free[2];
        // The scalar reference: everything up to the first stop token,
        // inclusive.
        let cut = free.iter().position(|&t| t == stop).unwrap();
        let want = &free[..=cut];
        let mut e = Engine::new(Arc::clone(&m), EngineConfig::default());
        let mut q = Batcher::default();
        q.push(req(0, prompt).with_stop_tokens(vec![stop]));
        let done = drain(&mut e, &mut q, 1);
        assert_eq!(done[0].tokens, want);
        assert_eq!(done[0].status, ResponseStatus::StoppedAtToken);
        assert_eq!(*done[0].tokens.last().unwrap(), stop);
    }

    #[test]
    fn stop_token_never_emitted_completes_normally() {
        let m = tiny();
        let prompt = vec![4, 5];
        let free = crate::coordinator::serve::generate(&m, &prompt, 6);
        let absent = (0..m.cfg.vocab).find(|t| !free.contains(t)).unwrap();
        let mut e =
            Engine::new(Arc::clone(&m), EngineConfig { gen_tokens: 6, ..Default::default() });
        let mut q = Batcher::default();
        q.push(req(0, prompt).with_stop_tokens(vec![absent]));
        let done = drain(&mut e, &mut q, 1);
        assert_eq!(done[0].tokens, free);
        assert_eq!(done[0].status, ResponseStatus::Complete);
    }

    #[test]
    fn phase_times_are_recorded_and_sum_within_step() {
        let m = tiny();
        let mut e = Engine::new(m, EngineConfig { gen_tokens: 4, ..Default::default() });
        let mut q = Batcher::default();
        q.push(req(0, vec![1, 2, 3]));
        q.push(req(1, vec![4, 5]));
        let done = drain(&mut e, &mut q, 2);
        assert_eq!(done.len(), 2);
        for f in &done {
            assert!(f.queue_wait <= f.enqueued.elapsed(), "queue wait exceeds request lifetime");
            if let Some(ftl) = f.first_token_latency {
                assert!(f.queue_wait <= ftl, "queue wait is a component of first-token latency");
            }
        }
        let t = e.telemetry().lock().unwrap().clone();
        let phase_sum = t.time_admit_s + t.time_prefill_s + t.time_decode_s + t.time_retire_s;
        assert!(phase_sum > 0.0, "phase clocks must run without tracing enabled");
        assert!(t.time_decode_s > 0.0, "decode happened");
        assert!(phase_sum <= t.time_step_s, "phases are sub-intervals of the step: {t:?}");
    }

    #[test]
    fn first_token_latency_is_recorded_and_ordered() {
        let m = tiny();
        let mut e = Engine::new(m, EngineConfig { gen_tokens: 3, ..Default::default() });
        let mut q = Batcher::default();
        q.push(req(0, vec![1, 2, 3]));
        let done = drain(&mut e, &mut q, 1);
        let ftl = done[0].first_token_latency.expect("generated ≥1 token");
        assert!(ftl <= done[0].enqueued.elapsed());
    }

    #[test]
    fn preemption_evicts_lower_tier_and_outputs_stay_bit_identical() {
        // One slot: a long-running Background resident blocks an
        // Interactive arrival. With preemption on, the resident is
        // evicted (its generated tokens saved), the Interactive request
        // runs first, and the victim readmits and recomputes — both
        // completions must still match the scalar reference exactly.
        let m = tiny();
        let cfg = EngineConfig {
            slots: 1,
            gen_tokens: 8,
            preemption: true,
            ..Default::default()
        };
        let mut e = Engine::new(Arc::clone(&m), cfg);
        let mut q = Batcher::default();
        let bg = vec![1, 2, 3];
        let hi = vec![4, 5];
        q.push(req(0, bg.clone()).with_priority(Priority::Background));
        // Let the Background sequence admit and emit a couple of tokens
        // before the Interactive request shows up.
        for _ in 0..4 {
            e.step(&mut q);
        }
        assert_eq!(e.occupied_slots(), 1);
        q.push(req(1, hi.clone()).with_priority(Priority::Interactive));
        let done = drain(&mut e, &mut q, 2);
        let by_id = |id: u64| done.iter().find(|f| f.id == id).unwrap();
        assert_eq!(by_id(0).tokens, crate::coordinator::serve::generate(&m, &bg, 8));
        assert_eq!(by_id(1).tokens, crate::coordinator::serve::generate(&m, &hi, 8));
        assert_eq!(by_id(0).status, ResponseStatus::Complete);
        assert_eq!(by_id(0).priority, Priority::Background);
        // The Interactive request finished strictly before the victim.
        let pos = |id: u64| done.iter().position(|f| f.id == id).unwrap();
        assert!(pos(1) < pos(0), "preemption must reorder completion");
        let t = e.telemetry().lock().unwrap().clone();
        assert!(t.preemptions >= 1, "the resident must have been evicted: {t:?}");
        assert!(t.victim_recompute_tokens > 0, "readmission recomputes the saved tokens");
        assert_eq!(t.joins, t.leaves, "evictions pair with readmissions");
        assert_eq!(t.pages_in_use_now, 0, "pages leaked across the preemption lifecycle");
    }

    #[test]
    fn preemption_requires_a_strictly_lower_tier_victim() {
        // Same-tier work must never preempt itself (no thrash): with two
        // Batch requests on one slot, the second simply waits.
        let m = tiny();
        let cfg = EngineConfig {
            slots: 1,
            gen_tokens: 4,
            preemption: true,
            ..Default::default()
        };
        let mut e = Engine::new(m, cfg);
        let mut q = Batcher::default();
        q.push(req(0, vec![1, 2]));
        e.step(&mut q);
        q.push(req(1, vec![3, 4]));
        let done = drain(&mut e, &mut q, 2);
        assert_eq!(done.len(), 2);
        let t = e.telemetry().lock().unwrap().clone();
        assert_eq!(t.preemptions, 0, "equal tiers must not preempt each other");
    }

    #[test]
    fn shed_drops_lowest_tier_and_accounting_balances() {
        // One slot, tiny SLO: the Background backlog behind an Interactive
        // request can never make its first token in time, so the shedder
        // drops it (newest first) instead of letting everything miss.
        let m = tiny();
        let cfg = EngineConfig {
            slots: 1,
            gen_tokens: 8,
            slo_first_token_steps: 3,
            shed_policy: ShedPolicy::LowestPriority,
            ..Default::default()
        };
        let mut e = Engine::new(Arc::clone(&m), cfg);
        let mut q = Batcher::default();
        q.push(req(0, vec![1, 2, 3, 4]).with_priority(Priority::Interactive));
        for i in 1..5u64 {
            q.push(req(i, vec![1, 2, 3, 4]).with_priority(Priority::Background));
        }
        let done = drain(&mut e, &mut q, 5);
        let shed: Vec<&FinishedSeq> =
            done.iter().filter(|f| f.status == ResponseStatus::Shed).collect();
        assert!(!shed.is_empty(), "the backlog must have been shed");
        assert!(shed.iter().all(|f| f.priority == Priority::Background), "only the lowest tier");
        assert!(shed.iter().all(|f| f.tokens.is_empty()), "never-admitted sheds carry no tokens");
        let ok = done.iter().find(|f| f.id == 0).unwrap();
        assert_eq!(ok.tokens.len(), 8, "the interactive request is untouched");
        let t = e.telemetry().lock().unwrap().clone();
        assert_eq!(t.shed, shed.len());
        // Accounting: every request leaves exactly once — shed from the
        // queue, or retired from a slot (leaves minus preemption evictions).
        assert_eq!(t.shed + (t.leaves - t.preemptions), 5);
        assert_eq!(t.joins, t.leaves);
        assert!(t.slo_hits >= 1, "the admitted request made its SLO: {t:?}");
        assert_eq!(t.pages_in_use_now, 0);
    }

    #[test]
    fn shed_policy_off_never_sheds_even_past_the_slo() {
        let m = tiny();
        let cfg = EngineConfig {
            slots: 1,
            gen_tokens: 8,
            slo_first_token_steps: 1,
            shed_policy: ShedPolicy::Off,
            ..Default::default()
        };
        let mut e = Engine::new(m, cfg);
        let mut q = Batcher::default();
        for i in 0..4u64 {
            q.push(req(i, vec![1, 2, 3]).with_priority(Priority::Background));
        }
        let done = drain(&mut e, &mut q, 4);
        assert!(done.iter().all(|f| f.status == ResponseStatus::Complete));
        assert_eq!(e.telemetry().lock().unwrap().shed, 0);
    }
}
