//! Synthetic language corpus with controlled structure.
//!
//! The generator is a sparse first-order Markov chain over a Zipf-weighted
//! vocabulary, with two kinds of planted long-range structure:
//!
//! * **facts** — trigger→answer pairs `(a ⇒ b at distance Δ)`: whenever `a`
//!   is emitted, `b` is force-emitted Δ steps later. Recalling `b` given the
//!   distant `a` requires attention, giving a "hard" task whose accuracy
//!   degrades first under compression (the MMLU proxy).
//! * **templates** — high-probability bigrams, the "easy" local structure
//!   (zero-shot proxy).
//!
//! The same generator provides train, calibration, and held-out evaluation
//! streams from independent seeds.

use crate::util::prng::Rng;

#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub vocab: usize,
    /// Markov successors per token.
    pub branching: usize,
    /// Probability mass on the Markov structure (rest is Zipf noise).
    pub structure_prob: f64,
    /// Number of planted fact pairs.
    pub n_facts: usize,
    /// Fact distance Δ.
    pub fact_gap: usize,
    /// Probability a fact trigger fires at any position.
    pub fact_rate: f64,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            vocab: 256,
            branching: 4,
            structure_prob: 0.85,
            n_facts: 24,
            fact_gap: 8,
            fact_rate: 0.06,
            seed: 0xC0FFEE,
        }
    }
}

impl CorpusConfig {
    pub fn for_vocab(vocab: usize, seed: u64) -> CorpusConfig {
        CorpusConfig { vocab, n_facts: (vocab / 10).max(8), seed, ..Default::default() }
    }
}

/// One (inputs, targets) batch: `targets[i] = inputs[i+1]` per sequence.
#[derive(Clone, Debug)]
pub struct Batch {
    pub inputs: Vec<Vec<usize>>,  // [batch][seq]
    pub targets: Vec<Vec<usize>>, // [batch][seq]
}

/// The corpus: fixed transition structure + per-stream emission state.
pub struct SyntheticCorpus {
    pub cfg: CorpusConfig,
    /// successors[t] = the `branching` likely next tokens after t.
    pub successors: Vec<Vec<usize>>,
    /// successor probability weights (Zipf over the branch slots).
    branch_weights: Vec<f64>,
    /// Zipf weights over the full vocabulary (noise distribution).
    zipf: Vec<f64>,
    /// fact pairs: trigger token → answer token.
    pub facts: Vec<(usize, usize)>,
}

impl SyntheticCorpus {
    pub fn new(cfg: CorpusConfig) -> SyntheticCorpus {
        let mut rng = Rng::new(cfg.seed);
        let successors: Vec<Vec<usize>> = (0..cfg.vocab)
            .map(|_| (0..cfg.branching).map(|_| rng.below(cfg.vocab)).collect())
            .collect();
        let branch_weights: Vec<f64> =
            (0..cfg.branching).map(|i| 1.0 / (i + 1) as f64).collect();
        let zipf: Vec<f64> = (0..cfg.vocab).map(|i| 1.0 / (i + 1) as f64).collect();
        // Facts use distinct trigger tokens (and avoid token 0 which is
        // heavily used by the Zipf noise).
        let mut triggers: Vec<usize> = (1..cfg.vocab).collect();
        rng.shuffle(&mut triggers);
        let facts: Vec<(usize, usize)> = triggers
            .iter()
            .take(cfg.n_facts)
            .map(|&a| (a, rng.range(1, cfg.vocab)))
            .collect();
        SyntheticCorpus { cfg, successors, branch_weights, zipf, facts }
    }

    fn fact_answer(&self, trigger: usize) -> Option<usize> {
        self.facts.iter().find(|&&(a, _)| a == trigger).map(|&(_, b)| b)
    }

    /// Generate one sequence of `len` tokens with the given stream rng.
    pub fn sequence(&self, len: usize, rng: &mut Rng) -> Vec<usize> {
        let mut out = Vec::with_capacity(len);
        // pending forced emissions: (position, token)
        let mut pending: Vec<(usize, usize)> = Vec::new();
        let mut cur = rng.below(self.cfg.vocab);
        for pos in 0..len {
            // Forced fact completion?
            let forced = pending
                .iter()
                .position(|&(p, _)| p == pos)
                .map(|i| pending.swap_remove(i).1);
            let tok = if let Some(t) = forced {
                t
            } else if rng.f64() < self.cfg.structure_prob {
                let slot = rng.weighted(&self.branch_weights);
                self.successors[cur][slot]
            } else {
                rng.weighted(&self.zipf)
            };
            // A trigger token always schedules its answer Δ steps out, so
            // the fact relation is fully reliable (learnable to ~100%).
            if let Some(ans) = self.fact_answer(tok) {
                let at = pos + self.cfg.fact_gap;
                if at < len && !pending.iter().any(|&(p, _)| p == at) {
                    pending.push((at, ans));
                }
            }
            out.push(tok);
            cur = tok;
        }
        out
    }

    /// A batch of next-token-prediction sequences.
    pub fn batch(&self, batch_size: usize, seq_len: usize, rng: &mut Rng) -> Batch {
        let mut inputs = Vec::with_capacity(batch_size);
        let mut targets = Vec::with_capacity(batch_size);
        for _ in 0..batch_size {
            let s = self.sequence(seq_len + 1, rng);
            inputs.push(s[..seq_len].to_vec());
            targets.push(s[1..].to_vec());
        }
        Batch { inputs, targets }
    }

    /// Independent deterministic stream (train=0, calib=1, eval=2, ...).
    pub fn stream(&self, stream_id: u64) -> Rng {
        Rng::new(self.cfg.seed ^ (0x5EED << 8) ^ stream_id.wrapping_mul(0x9E37_79B9))
    }

    /// “Hard” task instances (MMLU proxy): sequences where a fact trigger
    /// fired, returning (prefix ending right before the answer, answer).
    pub fn fact_probes(&self, n: usize, seq_len: usize, rng: &mut Rng) -> Vec<(Vec<usize>, usize)> {
        let mut probes = Vec::new();
        let gap = self.cfg.fact_gap;
        while probes.len() < n {
            let s = self.sequence(seq_len, rng);
            // find trigger positions whose answer landed in-sequence
            for i in 0..s.len().saturating_sub(gap) {
                if let Some(ans) = self.fact_answer(s[i]) {
                    if s[i + gap] == ans && i + gap >= 2 {
                        probes.push((s[..i + gap].to_vec(), ans));
                        if probes.len() >= n {
                            break;
                        }
                    }
                }
            }
        }
        probes
    }

    /// “Easy” task instances (zero-shot proxy): predict the most likely
    /// Markov successor after a structured context.
    pub fn bigram_probes(
        &self,
        n: usize,
        ctx_len: usize,
        rng: &mut Rng,
    ) -> Vec<(Vec<usize>, usize)> {
        let mut probes = Vec::new();
        while probes.len() < n {
            let s = self.sequence(ctx_len + 1, rng);
            let last = s[ctx_len - 1];
            // only probe when the actual continuation is the top successor
            let top = self.successors[last][0];
            if s[ctx_len] == top {
                probes.push((s[..ctx_len].to_vec(), top));
            }
        }
        probes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> SyntheticCorpus {
        SyntheticCorpus::new(CorpusConfig::default())
    }

    #[test]
    fn sequences_deterministic_per_stream() {
        let c = corpus();
        let a = c.sequence(100, &mut c.stream(1));
        let b = c.sequence(100, &mut c.stream(1));
        let d = c.sequence(100, &mut c.stream(2));
        assert_eq!(a, b);
        assert_ne!(a, d);
    }

    #[test]
    fn tokens_in_vocab() {
        let c = corpus();
        let s = c.sequence(1000, &mut c.stream(0));
        assert!(s.iter().all(|&t| t < c.cfg.vocab));
    }

    #[test]
    fn batch_shapes_and_shift() {
        let c = corpus();
        let b = c.batch(4, 32, &mut c.stream(3));
        assert_eq!(b.inputs.len(), 4);
        assert_eq!(b.inputs[0].len(), 32);
        assert_eq!(b.targets[0].len(), 32);
        // target is input shifted by one within the underlying sequence
        // (verified structurally: regenerate from the same stream)
        let mut rng = c.stream(3);
        let s = c.sequence(33, &mut rng);
        assert_eq!(b.inputs[0], s[..32].to_vec());
        assert_eq!(b.targets[0], s[1..].to_vec());
    }

    #[test]
    fn markov_structure_present() {
        // Next token should be a known successor far more often than chance.
        let c = corpus();
        let s = c.sequence(5000, &mut c.stream(4));
        let hits = s
            .windows(2)
            .filter(|w| c.successors[w[0]].contains(&w[1]))
            .count();
        let rate = hits as f64 / (s.len() - 1) as f64;
        assert!(rate > 0.5, "structure rate {rate}");
    }

    #[test]
    fn facts_fire_at_gap() {
        let c = corpus();
        let s = c.sequence(4000, &mut c.stream(5));
        let gap = c.cfg.fact_gap;
        let mut fired = 0;
        let mut honored = 0;
        for i in 0..s.len() - gap {
            if let Some(ans) = c.fact_answer(s[i]) {
                fired += 1;
                if s[i + gap] == ans {
                    honored += 1;
                }
            }
        }
        assert!(fired > 10, "need triggers in 4k tokens, got {fired}");
        let frac = honored as f64 / fired as f64;
        assert!(frac > 0.8, "facts honored only {frac}");
    }

    #[test]
    fn probes_well_formed() {
        let c = corpus();
        let probes = c.fact_probes(20, 64, &mut c.stream(6));
        assert_eq!(probes.len(), 20);
        for (ctx, ans) in &probes {
            assert!(!ctx.is_empty() && *ans < c.cfg.vocab);
            // trigger for ans must appear exactly gap before the end
            let trig = c.facts.iter().find(|&&(_, b)| b == *ans);
            assert!(trig.is_some() || true); // multiple facts may share answers
            assert!(ctx.len() >= c.cfg.fact_gap);
        }
        let bi = c.bigram_probes(20, 16, &mut c.stream(7));
        assert_eq!(bi.len(), 20);
        for (ctx, ans) in &bi {
            assert_eq!(ctx.len(), 16);
            assert_eq!(c.successors[ctx[15]][0], *ans);
        }
    }
}
