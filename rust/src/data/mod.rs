//! Synthetic datasets (DESIGN.md §3 substitutions).
//!
//! * [`corpus`] — a Zipf/Markov language with planted long-range "facts",
//!   standing in for C4 (calibration) and WikiText-2 (perplexity), and
//!   providing the task suites that proxy MMLU / zero-shot benchmarks.
//! * [`images`] — procedurally generated shape images standing in for
//!   ImageNet in the ViT experiments.

pub mod corpus;
pub mod images;

pub use corpus::{Batch, CorpusConfig, SyntheticCorpus};
pub use images::{ImageDataset, ImagesConfig};
