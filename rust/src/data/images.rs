//! Procedurally generated shape-classification images — the ImageNet
//! stand-in for the ViT experiments (Table 8, Figures 3–4).
//!
//! Each image is a grayscale `side × side` canvas with background noise and
//! one of eight shape classes drawn at a random position/scale. A small ViT
//! reaches high accuracy on this task, so compression-induced degradation is
//! measurable, and the shapes give attention rollout something spatial to
//! localize.

use crate::util::prng::Rng;

pub const N_CLASSES: usize = 8;

#[derive(Clone, Debug)]
pub struct ImagesConfig {
    pub side: usize,
    pub noise: f32,
    pub seed: u64,
}

impl Default for ImagesConfig {
    fn default() -> Self {
        ImagesConfig { side: 16, noise: 0.15, seed: 0x1A6E }
    }
}

/// A labelled image: row-major side×side pixels in [0,1].
#[derive(Clone, Debug)]
pub struct Image {
    pub pixels: Vec<f32>,
    pub label: usize,
}

pub struct ImageDataset {
    pub cfg: ImagesConfig,
}

impl ImageDataset {
    pub fn new(cfg: ImagesConfig) -> ImageDataset {
        ImageDataset { cfg }
    }

    pub fn stream(&self, stream_id: u64) -> Rng {
        Rng::new(self.cfg.seed ^ stream_id.wrapping_mul(0xD1B5_4A32_D192_ED03))
    }

    /// Generate one image of the given class.
    pub fn render(&self, label: usize, rng: &mut Rng) -> Image {
        let s = self.cfg.side;
        let mut px = vec![0.0f32; s * s];
        for p in px.iter_mut() {
            *p = rng.f32() * self.cfg.noise;
        }
        // Random placement box.
        let size = rng.range(s / 2, s.max(3) - 1);
        let x0 = rng.range(0, s - size);
        let y0 = rng.range(0, s - size);
        let fg = 0.7 + 0.3 * rng.f32();
        let set = |px: &mut Vec<f32>, x: usize, y: usize| {
            if x < s && y < s {
                px[y * s + x] = fg;
            }
        };
        match label {
            0 => {
                // filled square
                for y in y0..y0 + size {
                    for x in x0..x0 + size {
                        set(&mut px, x, y);
                    }
                }
            }
            1 => {
                // hollow square (frame)
                for i in 0..size {
                    set(&mut px, x0 + i, y0);
                    set(&mut px, x0 + i, y0 + size - 1);
                    set(&mut px, x0, y0 + i);
                    set(&mut px, x0 + size - 1, y0 + i);
                }
            }
            2 => {
                // disk
                let c = size as f32 / 2.0;
                for y in 0..size {
                    for x in 0..size {
                        let dx = x as f32 - c + 0.5;
                        let dy = y as f32 - c + 0.5;
                        if dx * dx + dy * dy <= c * c {
                            set(&mut px, x0 + x, y0 + y);
                        }
                    }
                }
            }
            3 => {
                // cross / plus
                let mid = size / 2;
                for i in 0..size {
                    set(&mut px, x0 + i, y0 + mid);
                    set(&mut px, x0 + mid, y0 + i);
                }
            }
            4 => {
                // horizontal stripes
                for y in (0..size).step_by(2) {
                    for x in 0..size {
                        set(&mut px, x0 + x, y0 + y);
                    }
                }
            }
            5 => {
                // vertical stripes
                for x in (0..size).step_by(2) {
                    for y in 0..size {
                        set(&mut px, x0 + x, y0 + y);
                    }
                }
            }
            6 => {
                // checkerboard
                for y in 0..size {
                    for x in 0..size {
                        if (x + y) % 2 == 0 {
                            set(&mut px, x0 + x, y0 + y);
                        }
                    }
                }
            }
            7 => {
                // main diagonal band
                for i in 0..size {
                    set(&mut px, x0 + i, y0 + i);
                    if i + 1 < size {
                        set(&mut px, x0 + i + 1, y0 + i);
                    }
                }
            }
            _ => panic!("label {label} out of range"),
        }
        Image { pixels: px, label }
    }

    /// A balanced batch of n images with labels cycling through classes.
    pub fn batch(&self, n: usize, rng: &mut Rng) -> Vec<Image> {
        (0..n).map(|i| self.render(i % N_CLASSES, rng)).collect()
    }

    /// Flatten images into (pixels matrix [n × side²], labels).
    pub fn to_matrix(&self, imgs: &[Image]) -> (crate::tensor::Matrix, Vec<usize>) {
        let s2 = self.cfg.side * self.cfg.side;
        let mut m = crate::tensor::Matrix::zeros(imgs.len(), s2);
        let mut labels = Vec::with_capacity(imgs.len());
        for (i, img) in imgs.iter().enumerate() {
            m.row_mut(i).copy_from_slice(&img.pixels);
            labels.push(img.label);
        }
        (m, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_classes() {
        let ds = ImageDataset::new(ImagesConfig::default());
        let mut rng = ds.stream(0);
        for label in 0..N_CLASSES {
            let img = ds.render(label, &mut rng);
            assert_eq!(img.pixels.len(), 16 * 16);
            assert_eq!(img.label, label);
            // foreground must exist and exceed the noise floor
            let max = img.pixels.iter().cloned().fold(0f32, f32::max);
            assert!(max > 0.5, "class {label} max {max}");
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean pixel mass differs across e.g. filled square vs frame.
        let ds = ImageDataset::new(ImagesConfig { noise: 0.0, ..Default::default() });
        let mut rng = ds.stream(1);
        let filled: f32 = ds.render(0, &mut rng).pixels.iter().sum();
        let hollow: f32 = ds.render(1, &mut rng).pixels.iter().sum();
        assert!(filled > hollow);
    }

    #[test]
    fn batch_is_balanced() {
        let ds = ImageDataset::new(ImagesConfig::default());
        let imgs = ds.batch(32, &mut ds.stream(2));
        let count0 = imgs.iter().filter(|i| i.label == 0).count();
        assert_eq!(count0, 4);
        let (m, labels) = ds.to_matrix(&imgs);
        assert_eq!(m.rows, 32);
        assert_eq!(labels.len(), 32);
    }

    #[test]
    fn deterministic_streams() {
        let ds = ImageDataset::new(ImagesConfig::default());
        let a = ds.render(3, &mut ds.stream(9));
        let b = ds.render(3, &mut ds.stream(9));
        assert_eq!(a.pixels, b.pixels);
    }
}
