//! End-to-end pipeline tests over the native stack (no artifacts needed):
//! compression quality ordering, engine-format equivalence, and the full
//! serving path on compressed weights.

use oats::calib::CalibSet;
use oats::config::{CompressConfig, Method, ModelConfig};
use oats::coordinator::pipeline::compress_clone;
use oats::data::{CorpusConfig, SyntheticCorpus};
use oats::model::TransformerLM;
use std::sync::Arc;

fn setup() -> (TransformerLM, SyntheticCorpus, CalibSet) {
    let cfg = ModelConfig::preset("tiny").unwrap();
    let model = TransformerLM::init(&cfg, 0xE2E);
    let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, 0xE2E));
    let calib = CalibSet::sample(&corpus, 8, 32, 4);
    (model, corpus, calib)
}

#[test]
fn compressed_model_logits_stay_close_at_low_rate() {
    let (model, corpus, calib) = setup();
    let cfg = CompressConfig {
        method: Method::Oats,
        rate: 0.3,
        rank_ratio: 0.25,
        iters: 10,
        ..Default::default()
    };
    let (cm, _) = compress_clone(&model, &calib, &cfg, 4).unwrap();
    let b = corpus.batch(4, 32, &mut corpus.stream(5));
    let div = oats::eval::logit_divergence(&model, &cm, &b.inputs);
    assert!(div < 0.5, "30% OATS distorted logits too much: {div}");
    let agree = oats::eval::prediction_agreement(&model, &cm, &b.inputs);
    assert!(agree > 0.6, "prediction agreement {agree}");
}

#[test]
fn oats_preserves_model_better_than_magnitude_at_high_rate() {
    // Requires a *trained* model: random-init weights have neither outlier
    // activations nor low-rank structure, so all pruners tie there. The
    // trained tiny model is produced by `oats train --preset tiny` (or any
    // experiment run); self-skip if absent.
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("models/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: models/tiny not trained yet (run `oats train --preset tiny`)");
        return;
    }
    let model = oats::model::io::load(&dir).unwrap();
    let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(model.cfg.vocab, 0xC0DE));
    let calib = CalibSet::sample(&corpus, 8, 32, 4);
    let b = corpus.batch(4, 32, &mut corpus.stream(6));
    let mut divs = std::collections::HashMap::new();
    for method in [Method::Magnitude, Method::Oats] {
        let cfg = CompressConfig {
            method,
            rate: 0.5,
            rank_ratio: 0.25,
            iters: 10,
            ..Default::default()
        };
        let (cm, _) = compress_clone(&model, &calib, &cfg, 4).unwrap();
        divs.insert(method.name(), oats::eval::logit_divergence(&model, &cm, &b.inputs));
    }
    assert!(
        divs["OATS"] < divs["Magnitude"],
        "OATS {} !< magnitude {}",
        divs["OATS"],
        divs["Magnitude"]
    );
}

#[test]
fn decode_path_matches_forward_on_compressed_model() {
    // KV-cached decode over SPL weights must equal the batched forward.
    let (model, _, calib) = setup();
    let cfg = CompressConfig {
        method: Method::Oats,
        rate: 0.5,
        rank_ratio: 0.3,
        iters: 5,
        ..Default::default()
    };
    let (cm, _) = compress_clone(&model, &calib, &cfg, 4).unwrap();
    let seq = vec![3usize, 14, 15, 9, 2, 6];
    let full = cm.forward(&[seq.clone()]);
    let mut cache = oats::model::KvCache::new(&cm.cfg);
    let mut last = Vec::new();
    for &t in &seq {
        last = cm.decode_step(t, &mut cache);
    }
    for (a, b) in last.iter().zip(full.row(seq.len() - 1)) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}

#[test]
fn serving_engine_runs_compressed_model() {
    let (model, _, calib) = setup();
    let cfg = CompressConfig {
        method: Method::Oats,
        rate: 0.4,
        rank_ratio: 0.25,
        iters: 4,
        ..Default::default()
    };
    let (cm, _) = compress_clone(&model, &calib, &cfg, 4).unwrap();
    let stats = oats::coordinator::serve::run_load(
        Arc::new(cm),
        oats::coordinator::serve::ServeConfig { slots: 4, gen_tokens: 4, ..Default::default() },
        (0..12).map(|i| vec![i % 16, 2, 3]).collect(),
    );
    assert_eq!(stats.n_requests, 12);
    assert_eq!(stats.tokens_generated, 48);
    assert!(stats.tokens_per_second() > 0.0);
    // Continuous-batching telemetry: every request joined a KV slot and
    // left it, and the decode batch stayed within the arena bound.
    assert_eq!(stats.joins, 12);
    assert_eq!(stats.leaves, 12);
    assert!(stats.batch_sizes.max <= 4.0);
    assert!(stats.slot_occupancy.mean > 0.0);
}

#[test]
fn quantized_serving_matches_direct_quantized_decode() {
    // Opting the server into i8 BCSR tiles must reproduce direct batched
    // decode through the same quantized kernels exactly (per-sequence
    // results are independent of how the dynamic batcher groups requests),
    // and at least one layer must actually carry a QBcsr plan.
    let (model, _, calib) = setup();
    let cfg = CompressConfig {
        method: Method::Oats,
        rate: 0.4,
        rank_ratio: 0.25,
        iters: 4,
        ..Default::default()
    };
    let (cm, _) = compress_clone(&model, &calib, &cfg, 4).unwrap();
    let opts = oats::sparse::PackOptions::quantized(4);
    let packed = cm.packed_for_serving_with(&opts);
    let n_q = packed
        .kernel_plans()
        .iter()
        .filter(|(_, p)| p.choice == oats::sparse::KernelChoice::QBcsr)
        .count();
    assert!(n_q > 0, "no layer upgraded to qbcsr: {:?}", packed.kernel_plans());

    let prompts: Vec<Vec<usize>> = (0..6).map(|i| vec![i % 16, 2, 3]).collect();
    let scfg = oats::coordinator::serve::ServeConfig {
        slots: 4,
        gen_tokens: 5,
        quantize: true,
        ..Default::default()
    };
    let server = oats::coordinator::serve::Server::start(Arc::new(cm), scfg);
    let rxs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| server.submit(i as u64, p.clone()))
        .collect();
    let got: Vec<Vec<usize>> = rxs.into_iter().map(|rx| rx.recv().unwrap().tokens).collect();
    // Reference is batch-of-1 lockstep decode: the engine routes prefill
    // through the batched kernels too, whose per-row results are
    // batch-width independent (scalar-prefill references could differ in
    // the last ulps on packed layers).
    let want: Vec<Vec<usize>> = prompts
        .iter()
        .map(|p| oats::coordinator::serve::generate_lockstep(&packed, p, 5))
        .collect();
    assert_eq!(got, want);
}

#[test]
fn nm_compressed_model_validates_pattern_everywhere() {
    let (model, _, calib) = setup();
    let cfg = CompressConfig {
        method: Method::Oats,
        rate: 0.5,
        rank_ratio: 0.3,
        iters: 4,
        pattern: oats::config::SparsityPattern::Nm { n: 2, m: 8 },
        ..Default::default()
    };
    let (cm, _) = compress_clone(&model, &calib, &cfg, 4).unwrap();
    for (b, blk) in cm.blocks.iter().enumerate() {
        for name in oats::model::LINEAR_NAMES {
            if let oats::model::LinearOp::Compressed(
                oats::compress::CompressedLayer::Spl(spl),
            ) = blk.linear(name)
            {
                let dense = spl.sparse.to_dense();
                assert!(
                    oats::sparse::NmPattern::TWO_EIGHT.validates(&dense),
                    "block{b}.{name} violates 2:8"
                );
            } else {
                panic!("block{b}.{name} not SPL");
            }
        }
    }
}

#[test]
fn owl_pipeline_varies_rates_by_block() {
    let (model, _, calib) = setup();
    let cfg = CompressConfig {
        method: Method::Wanda,
        rate: 0.6,
        owl: true,
        ..Default::default()
    };
    let (_, report) = compress_clone(&model, &calib, &cfg, 4).unwrap();
    let rates = report.owl_rates.expect("owl rates recorded");
    assert_eq!(rates.len(), model.blocks.len());
}
