//! End-to-end tests for the rotate-and-slice pipeline: the rotation-only
//! exactness property across random models, bit-exact sliced checkpoint
//! round trips through the serving loader, and the continuous-batching
//! engine driving a sliced model with a capped prefix index.

use oats::calib::CalibSet;
use oats::compress::CompressedLayer;
use oats::config::{CompressConfig, Method, ModelConfig};
use oats::coordinator::pipeline::compress_clone;
use oats::coordinator::serve::{run_load, ServeConfig};
use oats::data::{CorpusConfig, SyntheticCorpus};
use oats::model::{LinearOp, TransformerLM};
use std::sync::Arc;

fn setup_seeded(seed: u64) -> (TransformerLM, SyntheticCorpus, CalibSet) {
    let cfg = ModelConfig::preset("tiny").unwrap();
    let model = TransformerLM::init(&cfg, seed);
    let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, seed ^ 0x5CE));
    let calib = CalibSet::sample(&corpus, 4, 16, 4);
    (model, corpus, calib)
}

#[test]
fn rotation_only_slice_is_exact_across_models() {
    // Slicing at rate 0 is a pure channel permutation of the FFN pair —
    // orthogonal, and commuting with the elementwise activation — so for
    // ANY model the logits must match dense to float-accumulation noise.
    // Property-tested across independently initialised models and corpora,
    // not just the one seed the unit tests use.
    oats::util::prop::check("rotation_only_exact", 4, |g| {
        let seed = g.rng().next_u64();
        let (model, corpus, calib) = setup_seeded(seed);
        let cfg = CompressConfig {
            method: Method::Dense,
            slice_rate: Some(0.0),
            ..Default::default()
        };
        let (m, _) = compress_clone(&model, &calib, &cfg, 2).unwrap();
        let b = corpus.batch(2, 16, &mut corpus.stream(7));
        let dense = model.forward(&b.inputs);
        let sliced = m.forward(&b.inputs);
        let norm = dense.data.iter().map(|x| (x * x) as f64).sum::<f64>().sqrt();
        let d = dense.fro_dist(&sliced);
        assert!(
            d < 1e-3 * norm.max(1.0),
            "seed {seed:#x}: rotation-only divergence {d} vs norm {norm}"
        );
    });
}

#[test]
fn sliced_checkpoint_round_trips_bit_exactly_through_serving_loader() {
    // Save a sliced+OATS model, reload through the packing loader the
    // server uses, and require the unpacked weights — and therefore the
    // logits of the unpacked form — to be bit-identical.
    let (model, _, calib) = setup_seeded(0x517CED);
    let cfg = CompressConfig {
        method: Method::Oats,
        rate: 0.4,
        rank_ratio: 0.25,
        iters: 3,
        slice_rate: Some(0.4),
        ..Default::default()
    };
    let (cm, _) = compress_clone(&model, &calib, &cfg, 2).unwrap();
    let dir = std::env::temp_dir().join(format!("oats_sliced_e2e_{}", std::process::id()));
    oats::model::compressed_io::save(&cm, &dir).unwrap();
    let loaded = oats::model::compressed_io::load(&dir).unwrap();
    for (b, (blk, blk2)) in cm.blocks.iter().zip(&loaded.blocks).enumerate() {
        for name in ["up", "down"] {
            match (blk.linear(name), blk2.linear(name)) {
                (
                    LinearOp::Compressed(CompressedLayer::SlicedDense { w, in_map, out_map }),
                    LinearOp::Compressed(CompressedLayer::SlicedDense {
                        w: w2,
                        in_map: i2,
                        out_map: o2,
                    }),
                ) => {
                    assert_eq!(w.data, w2.data, "block{b}.{name} weight bits");
                    assert_eq!(in_map, i2, "block{b}.{name} in_map");
                    assert_eq!(out_map, o2, "block{b}.{name} out_map");
                }
                other => panic!("block{b}.{name} did not round-trip sliced: {other:?}"),
            }
        }
    }
    let toks = vec![vec![1usize, 2, 3, 4, 5, 6, 7, 8]];
    assert_eq!(
        cm.forward(&toks).data,
        loaded.forward(&toks).data,
        "bit-exact weights must give bit-exact logits"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn serving_engine_runs_sliced_model_with_capped_prefix_index() {
    // The full serving path on a sliced model: paged KV arena, prefix
    // reuse with a capacity-capped index, per-request completion. Capping
    // the index must change which pages stay resident, never what any
    // request receives — checked via the order-independent completions
    // digest against an uncapped run of the same workload.
    let (model, _, calib) = setup_seeded(0x5E12);
    let cfg = CompressConfig {
        method: Method::Oats,
        rate: 0.4,
        rank_ratio: 0.25,
        iters: 3,
        slice_rate: Some(0.25),
        ..Default::default()
    };
    let (cm, _) = compress_clone(&model, &calib, &cfg, 2).unwrap();
    let cm = Arc::new(cm);
    // Disjoint prompt groups so successive publishes churn the capped
    // index. With 2 slots and 2 requests per group, group i+2's first
    // admission implies group i has fully retired (FCFS over 2 slots),
    // so its published entries are unreferenced by then and the insert
    // at cap 1 must evict them — deterministically, any interleaving.
    let prompts: Vec<Vec<usize>> = (0..6)
        .map(|i| {
            let g = i / 2;
            (0..10).map(|j| (g * 11 + j + 1) % 16).collect()
        })
        .collect();
    let scfg = ServeConfig {
        slots: 2,
        gen_tokens: 4,
        page_size: 4,
        kv_pages: 24,
        prefix_cap: 1,
        ..Default::default()
    };
    let capped = run_load(Arc::clone(&cm), scfg.clone(), prompts.clone());
    let uncapped = run_load(cm, ServeConfig { prefix_cap: 0, ..scfg }, prompts);
    assert_eq!(capped.n_requests, 6);
    assert!(capped.tokens_per_second() > 0.0);
    assert_eq!(capped.pages_in_use_at_drain, 0, "capped run leaked pages");
    assert_eq!(uncapped.pages_in_use_at_drain, 0, "uncapped run leaked pages");
    assert!(
        capped.prefix_evictions_cap > 0,
        "cap 1 under 3 disjoint prefix groups must evict"
    );
    assert_eq!(uncapped.prefix_evictions_cap, 0, "unbounded index never cap-evicts");
    assert_eq!(
        capped.completions_digest, uncapped.completions_digest,
        "prefix-cap policy must not change completions"
    );
}
