//! Continuous-batching engine contracts:
//!
//! 1. **Parity** — per-sequence outputs are identical to scalar
//!    [`generate`] / [`generate_lockstep`] under randomized arrival times,
//!    prompt lengths, slot counts, prefill-chunk sizes, generation
//!    budgets, admission policies, **and KV page geometries** (the
//!    batched-vs-scalar parity test is the template).
//! 2. **Continuity** — under a mixed-length load the engine backfills
//!    retired slots immediately, so mean slot occupancy beats what the old
//!    static batch-at-a-time loop could achieve on the same workload; and
//!    at equal total KV bytes, small pages admit more concurrent
//!    sequences than whole-cache slots.
//! 3. **Conservation** — the paged arena leaks no pages across churn.

use oats::config::ModelConfig;
use oats::coordinator::engine::{
    AdmissionPolicy, Batcher, Engine, EngineConfig, FinishedSeq, Priority, Request,
    ResponseStatus, SeqEvent, ShedPolicy,
};
use oats::coordinator::serve::{generate, generate_lockstep};
use oats::model::TransformerLM;
use oats::util::prop::check;
use oats::util::trace;
use std::collections::HashMap;
use std::sync::Arc;

fn tiny() -> Arc<TransformerLM> {
    Arc::new(TransformerLM::init(&ModelConfig::preset("tiny").unwrap(), 0x5E4E))
}

/// The status the engine must report for a prompt of `len` under a
/// generation budget `gen` and KV capacity `cap`: oversized prompts are
/// rejected, trivially empty work completes, and a sequence whose KV
/// room runs out before its budget is capacity-stopped.
fn expected_status(len: usize, gen: usize, cap: usize) -> ResponseStatus {
    if len > cap {
        ResponseStatus::Truncated
    } else if len == 0 || gen == 0 || len + gen <= cap {
        ResponseStatus::Complete
    } else {
        ResponseStatus::CapacityStopped
    }
}

/// Drive an engine synchronously: `arrivals[i] = (step, prompt)` enters the
/// admission queue at the start of that engine step. Returns finished
/// sequences by request id.
fn drive(
    model: &Arc<TransformerLM>,
    cfg: EngineConfig,
    arrivals: &[(usize, Vec<usize>)],
) -> (HashMap<u64, FinishedSeq>, Engine) {
    drive_with(model, cfg, arrivals, Request::new)
}

/// [`drive`] with per-request construction control, so tests can opt
/// requests out of prefix sharing or attach stop tokens.
fn drive_with(
    model: &Arc<TransformerLM>,
    cfg: EngineConfig,
    arrivals: &[(usize, Vec<usize>)],
    make: impl Fn(u64, Vec<usize>) -> Request,
) -> (HashMap<u64, FinishedSeq>, Engine) {
    let mut engine = Engine::new(Arc::clone(model), cfg);
    let mut queue = Batcher::default();
    let mut done = HashMap::new();
    let mut step = 0usize;
    while done.len() < arrivals.len() {
        assert!(step < 10_000, "engine stalled at {}/{}", done.len(), arrivals.len());
        for (id, (at, prompt)) in arrivals.iter().enumerate() {
            if *at == step {
                let prompt = prompt.clone();
                queue.push(make(id as u64, prompt));
            }
        }
        for ev in engine.step(&mut queue) {
            if let SeqEvent::Finished(f) = ev {
                assert!(done.insert(f.id, f).is_none(), "sequence finished twice");
            }
        }
        step += 1;
    }
    (done, engine)
}

#[test]
fn engine_matches_scalar_generate_under_randomized_arrivals() {
    let m = tiny();
    let cap = m.cfg.seq_len;
    check("continuous batching == scalar generate", 12, |g| {
        let cfg = EngineConfig {
            slots: g.usize_range(1, 5),
            prefill_chunk: g.usize_range(1, 7),
            gen_tokens: g.usize_range(0, 7),
            admission: if g.bool() {
                AdmissionPolicy::Fcfs
            } else {
                AdmissionPolicy::ShortestPrompt
            },
            ..Default::default()
        };
        let n_req = g.usize_range(1, 8);
        let arrivals: Vec<(usize, Vec<usize>)> = (0..n_req)
            .map(|_| {
                // Lengths cover empty, ordinary, near-capacity, and
                // oversized prompts; arrivals are scattered so sequences
                // join mid-decode.
                let len = match g.usize_range(0, 10) {
                    0 => 0,
                    1 => cap,
                    2 => cap + g.usize_range(1, 4),
                    _ => g.usize_range(1, 17),
                };
                let prompt = (0..len).map(|_| g.usize_range(0, m.cfg.vocab)).collect();
                (g.usize_range(0, 7), prompt)
            })
            .collect();
        let (done, _) = drive(&m, cfg, &arrivals);
        assert_eq!(done.len(), n_req);
        for (id, (_, prompt)) in arrivals.iter().enumerate() {
            let f = &done[&(id as u64)];
            assert_eq!(
                f.status,
                expected_status(prompt.len(), cfg.gen_tokens, cap),
                "prompt len {} under {cfg:?}",
                prompt.len()
            );
            if prompt.len() > cap {
                assert!(f.tokens.is_empty(), "rejected request must not generate");
            } else {
                assert_eq!(
                    f.tokens,
                    generate(&m, prompt, cfg.gen_tokens),
                    "prompt len {} under {cfg:?}",
                    prompt.len()
                );
            }
        }
    });
}

#[test]
fn paged_engine_matches_lockstep_under_randomized_page_geometry() {
    // The paging tentpole's parity contract: for ANY page geometry —
    // single-position pages, ragged last pages, whole-sequence pages —
    // and any arrival pattern, per-sequence outputs equal the batch-of-1
    // lockstep reference through the same kernels, and the arena
    // conserves its pages across all the churn.
    let m = tiny();
    let cap = m.cfg.seq_len;
    check("paged engine == generate_lockstep", 10, |g| {
        let slots = g.usize_range(1, 5);
        let page_size = g.usize_range(1, cap + 5); // may exceed cap: clamped
        let per_seq = cap.div_ceil(page_size.min(cap));
        // From barely-one-sequence up to everything-fits.
        let kv_pages = g.usize_range(per_seq, slots * per_seq + 1);
        let cfg = EngineConfig {
            slots,
            prefill_chunk: g.usize_range(1, 7),
            gen_tokens: g.usize_range(1, 9),
            admission: if g.bool() {
                AdmissionPolicy::Fcfs
            } else {
                AdmissionPolicy::ShortestPrompt
            },
            page_size,
            kv_pages,
            prefix_cap: 0,
            ..Default::default()
        };
        let n_req = g.usize_range(1, 8);
        let arrivals: Vec<(usize, Vec<usize>)> = (0..n_req)
            .map(|_| {
                let len = match g.usize_range(0, 8) {
                    0 => cap,
                    1 => cap - g.usize_range(1, 5),
                    _ => g.usize_range(1, 25),
                };
                let prompt = (0..len).map(|_| g.usize_range(0, m.cfg.vocab)).collect();
                (g.usize_range(0, 6), prompt)
            })
            .collect();
        let (done, engine) = drive(&m, cfg, &arrivals);
        assert_eq!(done.len(), n_req);
        for (id, (_, prompt)) in arrivals.iter().enumerate() {
            let f = &done[&(id as u64)];
            assert_eq!(f.status, expected_status(prompt.len(), cfg.gen_tokens, cap));
            assert_eq!(
                f.tokens,
                generate_lockstep(&m, prompt, cfg.gen_tokens),
                "prompt len {} under {cfg:?}",
                prompt.len()
            );
        }
        let t = engine.telemetry().lock().unwrap().clone();
        assert_eq!(t.pages_in_use_now, 0, "pages leaked after drain under {cfg:?}");
        assert!(
            t.pages_in_use.iter().all(|&p| p <= t.total_pages as f64),
            "pages over-committed under {cfg:?}: {:?}",
            t.pages_in_use
        );
        assert!(t.page_occupancy.iter().all(|&o| (0.0..=1.0).contains(&o)));
    });
}

#[test]
fn equal_kv_bytes_paged_arena_admits_more_concurrency() {
    // The acceptance criterion for the paging tentpole. Same model, same
    // mixed-length workload, same total KV bytes:
    //   whole-cache: 2 slots × one 64-position cache  = 128 positions
    //   paged:       8 slots over 16 pages × 8 positions = 128 positions
    // Short sequences (≈12–16 positions end to end) strand most of a
    // whole cache but hold only 2 pages, so the paged arena runs several
    // of them concurrently where the whole-cache arena fits two.
    let m = tiny();
    let cap = m.cfg.seq_len;
    assert_eq!(cap, 64, "workload sizing below assumes the tiny preset");
    let gen = 4usize;
    let arrivals: Vec<(usize, Vec<usize>)> = (0..10)
        .map(|i| (0usize, (0..(8 + (i * 3) % 5)).map(|j| (i * 7 + j) % 16).collect()))
        .collect();

    let whole = EngineConfig {
        slots: 2,
        prefill_chunk: 4,
        gen_tokens: gen,
        admission: AdmissionPolicy::Fcfs,
        page_size: 0,
        kv_pages: 0,
        prefix_cap: 0,
        ..Default::default()
    };
    let paged = EngineConfig { slots: 8, page_size: 8, kv_pages: 16, ..whole };

    let (done_w, engine_w) = drive(&m, whole, &arrivals);
    let (done_p, engine_p) = drive(&m, paged, &arrivals);
    // Outputs identical to the lockstep reference in both arenas.
    for (id, (_, prompt)) in arrivals.iter().enumerate() {
        let want = generate_lockstep(&m, prompt, gen);
        assert_eq!(done_w[&(id as u64)].tokens, want);
        assert_eq!(done_p[&(id as u64)].tokens, want);
    }
    let tw = engine_w.telemetry().lock().unwrap().clone();
    let tp = engine_p.telemetry().lock().unwrap().clone();
    assert_eq!(tw.kv_bytes, tp.kv_bytes, "comparison must hold KV bytes equal");
    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
    // Resident sequences per step (occupancy × slots) and decode width
    // must both rise when the same bytes are sliced into pages.
    let resident_w = mean(&tw.occupancy) * tw.slots as f64;
    let resident_p = mean(&tp.occupancy) * tp.slots as f64;
    assert!(
        resident_p > resident_w,
        "paged arena must admit more concurrent sequences: {resident_p:.2} vs {resident_w:.2}"
    );
    assert!(
        mean(&tp.decode_batch) > mean(&tw.decode_batch),
        "paged arena must decode wider: {:.2} vs {:.2}",
        mean(&tp.decode_batch),
        mean(&tw.decode_batch)
    );
    let peak = |xs: &[f64]| xs.iter().cloned().fold(0.0f64, f64::max);
    assert!(peak(&tp.decode_batch) > peak(&tw.decode_batch));
    // And the paged run still finishes the workload in fewer steps.
    assert!(tp.steps < tw.steps, "paged {} steps vs whole-cache {}", tp.steps, tw.steps);
    assert_eq!(tp.pages_in_use_now, 0);
}

#[test]
fn mixed_length_load_beats_static_batching_occupancy() {
    // Workload chosen so the static comparison is exact. A sequence holds
    // its slot for ceil(len/chunk) prefill steps — the last of which also
    // decodes its first token — plus gen-1 further decode steps: with
    // chunk = 1, service_i = len_i + gen - 1. Prompt lengths below are
    // b_i + 1 - gen, so service_i == b_i exactly. The old static batcher
    // ran FIFO waves of `slots` sequences and held every slot until the
    // wave's longest sequence drained, so its occupancy on this workload
    // is the closed-form number computed here — which the engine's
    // same-step backfill must beat.
    let m = tiny();
    let budgets = [2usize, 12, 2, 12, 2, 12];
    let slots = 2usize;
    // Static waves: [2,12], [2,12], [2,12] → each wave lasts max = 12
    // steps; busy slot-steps per wave = 2 + 12.
    let wave_steps: usize = budgets.chunks(slots).map(|w| *w.iter().max().unwrap()).sum();
    let busy: usize = budgets.iter().sum();
    let static_occupancy = busy as f64 / (slots * wave_steps) as f64;

    // The engine has one server-wide gen_tokens, so mixed service lengths
    // are emulated with mixed *prompt* lengths (service = len + gen - 1).
    let gen = 2usize;
    let cfg = EngineConfig {
        slots,
        prefill_chunk: 1,
        gen_tokens: gen,
        admission: AdmissionPolicy::Fcfs,
        ..Default::default()
    };
    let arrivals: Vec<(usize, Vec<usize>)> = budgets
        .iter()
        .map(|&b| (0usize, (0..(b + 1 - gen)).map(|j| (j * 3) % m.cfg.vocab).collect()))
        .collect();
    let (done, engine) = drive(&m, cfg, &arrivals);
    assert_eq!(done.len(), budgets.len());
    let t = engine.telemetry().lock().unwrap().clone();
    let engine_occupancy = t.occupancy.iter().sum::<f64>() / t.occupancy.len() as f64;
    assert!(
        engine_occupancy > static_occupancy,
        "continuous batching must beat static occupancy: {engine_occupancy:.3} vs \
         {static_occupancy:.3} (occupancy trace {:?})",
        t.occupancy
    );
    assert_eq!(t.joins, budgets.len());
    assert_eq!(t.leaves, budgets.len());
    // Short sequences leave and their slots are re-used while long ones
    // keep decoding — the engine also finishes the whole workload sooner
    // than the static waves would.
    assert!(t.steps < wave_steps, "engine took {} steps vs static {}", t.steps, wave_steps);
}

#[test]
fn per_request_budgets_match_scalar_generate_under_arrivals() {
    // Mixed per-request budgets through the continuous-batching engine:
    // every request's output must equal scalar `generate` under its OWN
    // resolved budget, and its status must follow that budget (a
    // zero-budget request completes empty without a slot; a near-capacity
    // prompt with a big budget is capacity-stopped).
    let m = tiny();
    let cap = m.cfg.seq_len;
    check("per-request budgets == scalar generate", 10, |g| {
        let default_gen = g.usize_range(1, 6);
        let cfg = EngineConfig {
            slots: g.usize_range(1, 4),
            prefill_chunk: g.usize_range(1, 7),
            gen_tokens: default_gen,
            admission: if g.bool() {
                AdmissionPolicy::Fcfs
            } else {
                AdmissionPolicy::ShortestPrompt
            },
            ..Default::default()
        };
        let n_req = g.usize_range(1, 7);
        let arrivals: Vec<(usize, Vec<usize>, Option<usize>)> = (0..n_req)
            .map(|_| {
                let len = match g.usize_range(0, 8) {
                    0 => 0,
                    1 => cap - g.usize_range(0, 3),
                    _ => g.usize_range(1, 15),
                };
                let prompt = (0..len).map(|_| g.usize_range(0, m.cfg.vocab)).collect();
                let budget = if g.bool() { Some(g.usize_range(0, 9)) } else { None };
                (g.usize_range(0, 5), prompt, budget)
            })
            .collect();
        let mut engine = Engine::new(Arc::clone(&m), cfg);
        let mut queue = Batcher::default();
        let mut done: HashMap<u64, FinishedSeq> = HashMap::new();
        let mut step = 0usize;
        while done.len() < arrivals.len() {
            assert!(step < 10_000, "engine stalled");
            for (id, (at, prompt, budget)) in arrivals.iter().enumerate() {
                if *at == step {
                    let mut r = Request::new(id as u64, prompt.clone());
                    r.gen_tokens = *budget;
                    queue.push(r);
                }
            }
            for ev in engine.step(&mut queue) {
                if let SeqEvent::Finished(f) = ev {
                    assert!(done.insert(f.id, f).is_none());
                }
            }
            step += 1;
        }
        for (id, (_, prompt, budget)) in arrivals.iter().enumerate() {
            let gen = budget.unwrap_or(default_gen);
            let f = &done[&(id as u64)];
            assert_eq!(f.status, expected_status(prompt.len(), gen, cap), "budget {budget:?}");
            assert_eq!(f.tokens, generate(&m, prompt, gen), "budget {budget:?}");
        }
    });
}

#[test]
fn shared_prefix_outputs_bit_identical_to_unshared_and_leak_free() {
    // The shared-prefix tentpole's parity contract: prefix-KV reuse is an
    // *optimization*, never a behaviour. For any page geometry, arrival
    // pattern, and divergence point — tails splitting mid-page, exact
    // page-aligned duplicates (the CoW fork path), unrelated prompts mixed
    // in — a run with sharing enabled must produce byte-identical tokens
    // and statuses to the same workload with every request opted out, both
    // equal to the lockstep scalar reference, and neither run may leak a
    // page (shared pages included) once drained.
    let m = tiny();
    let cap = m.cfg.seq_len;
    check("prefix sharing == no sharing == lockstep", 10, |g| {
        let slots = g.usize_range(2, 5);
        let page_size = g.usize_range(1, 13);
        let per_seq = cap.div_ceil(page_size);
        let kv_pages = g.usize_range(per_seq, slots * per_seq + 1);
        let cfg = EngineConfig {
            slots,
            prefill_chunk: g.usize_range(1, 7),
            gen_tokens: g.usize_range(1, 6),
            admission: if g.bool() {
                AdmissionPolicy::Fcfs
            } else {
                AdmissionPolicy::ShortestPrompt
            },
            page_size,
            kv_pages,
            prefix_cap: 0,
            ..Default::default()
        };
        // A common system-prompt head most requests open with; tails
        // diverge at random points relative to page boundaries.
        let head: Vec<usize> =
            (0..g.usize_range(1, 13)).map(|_| g.usize_range(0, m.cfg.vocab)).collect();
        let n_req = g.usize_range(2, 8);
        let arrivals: Vec<(usize, Vec<usize>)> = (0..n_req)
            .map(|_| {
                let prompt = match g.usize_range(0, 8) {
                    // Exact duplicate of the head: if the head is
                    // page-aligned this forces a fork before the joiner's
                    // first decode write.
                    0 => head.clone(),
                    // Unrelated prompt: must neither match nor be disturbed.
                    1 => (0..g.usize_range(1, 17))
                        .map(|_| g.usize_range(0, m.cfg.vocab))
                        .collect(),
                    // Common head, divergent tail.
                    _ => {
                        let mut p = head.clone();
                        p.extend((0..g.usize_range(1, 13)).map(|_| g.usize_range(0, m.cfg.vocab)));
                        p
                    }
                };
                (g.usize_range(0, 8), prompt)
            })
            .collect();
        let (shared, eng_s) = drive(&m, cfg, &arrivals);
        let (unshared, eng_u) =
            drive_with(&m, cfg, &arrivals, |id, p| Request::new(id, p).without_prefix_sharing());
        for (id, (_, prompt)) in arrivals.iter().enumerate() {
            let s = &shared[&(id as u64)];
            let u = &unshared[&(id as u64)];
            assert_eq!(
                s.tokens,
                u.tokens,
                "sharing changed output for prompt len {} under {cfg:?}",
                prompt.len()
            );
            assert_eq!(s.status, u.status, "sharing changed status under {cfg:?}");
            assert_eq!(s.status, expected_status(prompt.len(), cfg.gen_tokens, cap));
            assert_eq!(
                s.tokens,
                generate_lockstep(&m, prompt, cfg.gen_tokens),
                "prompt len {} under {cfg:?}",
                prompt.len()
            );
        }
        let ts = eng_s.telemetry().lock().unwrap().clone();
        let tu = eng_u.telemetry().lock().unwrap().clone();
        assert_eq!(ts.pages_in_use_now, 0, "sharing run leaked pages under {cfg:?}");
        assert_eq!(tu.pages_in_use_now, 0, "opted-out run leaked pages under {cfg:?}");
        // Opting out must really opt out.
        assert_eq!(tu.shared_pages, 0);
        assert_eq!(tu.prefill_tokens_saved, 0);
        assert_eq!(tu.cow_forks, 0);
    });
}

#[test]
fn shared_prefix_load_saves_prefill_and_forks_on_duplicates() {
    // Deterministic end-to-end counter check: a donor publishes its two
    // head pages, three later arrivals join them (one an exact
    // page-aligned duplicate, which must fork before recomputing its last
    // prompt token), and the telemetry adds up exactly.
    let m = tiny();
    let gen = 2usize;
    let cfg = EngineConfig {
        slots: 3,
        prefill_chunk: 4,
        gen_tokens: gen,
        admission: AdmissionPolicy::Fcfs,
        page_size: 4,
        kv_pages: 12,
        prefix_cap: 0,
        ..Default::default()
    };
    let head: Vec<usize> = (0..8).map(|j| (j * 5 + 3) % m.cfg.vocab).collect();
    let with_tail = |tail: &[usize]| {
        let mut p = head.clone();
        p.extend_from_slice(tail);
        p
    };
    let arrivals: Vec<(usize, Vec<usize>)> = vec![
        // Donor: prefill covers both head pages by step 1, publishing them.
        (0, with_tail(&[1, 2])),
        // Joiner with a divergent tail: maps 2 pages, resumes at token 8.
        (4, with_tail(&[3])),
        // Exact page-aligned duplicate: maps 2 pages, resumes at token 7,
        // and must CoW-fork page 1 before rewriting position 7.
        (4, head.clone()),
        // Late joiner: the index still holds the head pages.
        (6, with_tail(&[4, 5, 6])),
    ];
    let (done, engine) = drive(&m, cfg, &arrivals);
    for (id, (_, prompt)) in arrivals.iter().enumerate() {
        assert_eq!(
            done[&(id as u64)].tokens,
            generate_lockstep(&m, prompt, gen),
            "request {id} diverged from the scalar reference"
        );
    }
    let t = engine.telemetry().lock().unwrap().clone();
    assert_eq!(t.shared_pages, 6, "three joiners × two mapped head pages");
    // Saved prefill: 8 (divergent tail) + 7 (duplicate resumes one early,
    // its last prompt token must be recomputed to produce logits) + 8.
    assert_eq!(t.prefill_tokens_saved, 23);
    assert_eq!(t.cow_forks, 1, "only the exact duplicate rewrites a shared page");
    assert_eq!(t.pages_in_use_now, 0, "drain must reclaim published pages too");
}

#[test]
fn stop_tokens_match_truncated_scalar_generate() {
    // Per-request stop tokens: output equals scalar `generate` truncated
    // at the first stop token *inclusive*, with StoppedAtToken status; a
    // request whose reference output never hits a stop token is untouched.
    let m = tiny();
    let cap = m.cfg.seq_len;
    check("stop tokens == truncated scalar generate", 10, |g| {
        let cfg = EngineConfig {
            slots: g.usize_range(1, 4),
            prefill_chunk: g.usize_range(1, 7),
            gen_tokens: g.usize_range(1, 9),
            admission: if g.bool() {
                AdmissionPolicy::Fcfs
            } else {
                AdmissionPolicy::ShortestPrompt
            },
            ..Default::default()
        };
        let n_req = g.usize_range(1, 6);
        let arrivals: Vec<(usize, Vec<usize>, Vec<usize>)> = (0..n_req)
            .map(|_| {
                let len = g.usize_range(1, 15);
                let prompt: Vec<usize> =
                    (0..len).map(|_| g.usize_range(0, m.cfg.vocab)).collect();
                // Half the time seed a stop token from the reference output
                // so stops actually fire mid-stream; always mix in random
                // vocab draws that may or may not ever be emitted.
                let full = generate(&m, &prompt, cfg.gen_tokens);
                let mut stops = Vec::new();
                if g.bool() && !full.is_empty() {
                    stops.push(full[g.usize_range(0, full.len())]);
                }
                for _ in 0..g.usize_range(0, 3) {
                    stops.push(g.usize_range(0, m.cfg.vocab));
                }
                (g.usize_range(0, 5), prompt, stops)
            })
            .collect();
        let mut engine = Engine::new(Arc::clone(&m), cfg);
        let mut queue = Batcher::default();
        let mut done: HashMap<u64, FinishedSeq> = HashMap::new();
        let mut step = 0usize;
        while done.len() < arrivals.len() {
            assert!(step < 10_000, "engine stalled");
            for (id, (at, prompt, stops)) in arrivals.iter().enumerate() {
                if *at == step {
                    queue.push(
                        Request::new(id as u64, prompt.clone()).with_stop_tokens(stops.clone()),
                    );
                }
            }
            for ev in engine.step(&mut queue) {
                if let SeqEvent::Finished(f) = ev {
                    assert!(done.insert(f.id, f).is_none());
                }
            }
            step += 1;
        }
        for (id, (_, prompt, stops)) in arrivals.iter().enumerate() {
            let f = &done[&(id as u64)];
            let full = generate(&m, prompt, cfg.gen_tokens);
            match full.iter().position(|t| stops.contains(t)) {
                Some(i) => {
                    assert_eq!(
                        f.status,
                        ResponseStatus::StoppedAtToken,
                        "stop at {i} under {cfg:?}"
                    );
                    assert_eq!(f.tokens, &full[..=i], "stop at {i} under {cfg:?}");
                }
                None => {
                    assert_eq!(f.status, expected_status(prompt.len(), cfg.gen_tokens, cap));
                    assert_eq!(f.tokens, full, "no stop token under {cfg:?}");
                }
            }
        }
    });
}

#[test]
fn tracing_observes_without_reordering_and_orders_lifecycle_events() {
    // Tracing is an observer, never a participant: the same workload with
    // the recorder on must produce byte-identical tokens and statuses, and
    // the recorded lifecycle instants must be complete and ordered per
    // request (enqueued <= admitted <= first_token <= retired).
    let m = tiny();
    let cfg = EngineConfig {
        slots: 3,
        prefill_chunk: 4,
        gen_tokens: 4,
        admission: AdmissionPolicy::Fcfs,
        page_size: 4,
        kv_pages: 24,
        prefix_cap: 0,
        ..Default::default()
    };
    // The trace flag and rings are process-global and tests in this binary
    // run in parallel, so this test claims an id range no other workload
    // uses and filters the drained events on it.
    const BASE: u64 = 100_000;
    let arrivals: Vec<(usize, Vec<usize>)> = (0..6)
        .map(|i| (i % 3, (0..(1 + (i * 5) % 11)).map(|j| (i * 7 + j) % 16).collect()))
        .collect();

    let (untraced, _) = drive_with(&m, cfg, &arrivals, |id, p| Request::new(BASE + id, p));
    trace::set_enabled(true);
    let (traced, _) = drive_with(&m, cfg, &arrivals, |id, p| Request::new(BASE + id, p));
    trace::set_enabled(false);
    let events = trace::drain();

    let mut times: HashMap<u64, HashMap<&str, u64>> = HashMap::new();
    for e in &events {
        if let Some(&(_, id)) = e.args.iter().find(|(k, _)| *k == "id") {
            if id as u64 >= BASE {
                times.entry(id as u64).or_default().insert(e.name, e.ts_ns);
            }
        }
    }
    for (id, (_, prompt)) in arrivals.iter().enumerate() {
        let key = BASE + id as u64;
        assert_eq!(
            traced[&key].tokens,
            untraced[&key].tokens,
            "tracing changed the output for prompt len {}",
            prompt.len()
        );
        assert_eq!(traced[&key].status, untraced[&key].status);
        let t = &times[&key];
        let (enq, adm) = (t["request_enqueued"], t["request_admitted"]);
        let (ft, ret) = (t["request_first_token"], t["request_retired"]);
        assert!(
            enq <= adm && adm <= ft && ft <= ret,
            "request {key} lifecycle out of order: {enq} {adm} {ft} {ret}"
        );
    }
    assert!(
        events
            .iter()
            .any(|e| e.name == "engine_step" && matches!(e.kind, trace::EventKind::Span { .. })),
        "traced run recorded no engine_step spans"
    );
}

#[test]
fn preemption_is_scheduling_never_behaviour_under_randomized_storms() {
    // The overload tentpole's parity contract: preemption decides WHEN a
    // sequence computes, never WHAT. For any slot count, page geometry,
    // priority assignment, and arrival scatter (shedding off, so nothing
    // is dropped), every request's tokens and status with preemption on
    // must equal the preemption-off run — both equal to the lockstep
    // scalar reference — and both arenas must drain leak-free with
    // joins == leaves (each eviction pairs with a readmission).
    let m = tiny();
    let cap = m.cfg.seq_len;
    let total_preemptions = std::cell::Cell::new(0usize);
    check("preemption on == preemption off", 10, |g| {
        let slots = g.usize_range(1, 4);
        // Whole-sequence pages or a page arena from barely-one-sequence
        // (maximum page pressure) up to everything-fits.
        let page_size = if g.bool() { 0 } else { 8 };
        let per_seq = if page_size == 0 { 1 } else { cap.div_ceil(page_size) };
        let kv_pages =
            if page_size == 0 { 0 } else { g.usize_range(per_seq, slots * per_seq + 1) };
        let prefill_chunk = g.usize_range(1, 7);
        let gen_tokens = g.usize_range(1, 7);
        let admission =
            if g.bool() { AdmissionPolicy::Fcfs } else { AdmissionPolicy::ShortestPrompt };
        let cfg = |preemption: bool| EngineConfig {
            slots,
            prefill_chunk,
            gen_tokens,
            admission,
            page_size,
            kv_pages,
            preemption,
            ..Default::default()
        };
        let n_req = g.usize_range(2, 10);
        let arrivals: Vec<(usize, Vec<usize>)> = (0..n_req)
            .map(|_| {
                let len = g.usize_range(1, 15);
                let prompt = (0..len).map(|_| g.usize_range(0, m.cfg.vocab)).collect();
                (g.usize_range(0, 10), prompt)
            })
            .collect();
        // Later arrivals lean interactive so storms of high-tier work land
        // on slots already held by lower tiers — the preemption trigger.
        let prios: Vec<Priority> = (0..n_req)
            .map(|i| match (g.usize_range(0, 4), i >= n_req / 2) {
                (0, _) | (_, true) => Priority::Interactive,
                (1, _) => Priority::Batch,
                _ => Priority::Background,
            })
            .collect();
        let make = |id: u64, p: Vec<usize>| Request::new(id, p).with_priority(prios[id as usize]);
        let (on, eng_on) = drive_with(&m, cfg(true), &arrivals, make);
        let (off, eng_off) = drive_with(&m, cfg(false), &arrivals, make);
        for (id, (_, prompt)) in arrivals.iter().enumerate() {
            let a = &on[&(id as u64)];
            let b = &off[&(id as u64)];
            assert_eq!(a.tokens, b.tokens, "preemption changed output for request {id}");
            assert_eq!(a.status, b.status, "preemption changed status for request {id}");
            assert_eq!(a.tokens, generate_lockstep(&m, prompt, gen_tokens));
        }
        let t_on = eng_on.telemetry().lock().unwrap().clone();
        let t_off = eng_off.telemetry().lock().unwrap().clone();
        assert_eq!(t_off.preemptions, 0, "preemption fired with the flag off");
        assert_eq!(t_on.shed + t_off.shed, 0, "nothing sheds with the policy off");
        assert_eq!(t_on.joins, t_on.leaves, "an eviction must pair with a readmission");
        assert_eq!(t_on.pages_in_use_now, 0, "preemption-on arena leaked pages");
        assert_eq!(t_off.pages_in_use_now, 0);
        if t_on.preemptions == 0 {
            assert_eq!(t_on.victim_recompute_tokens, 0);
        }
        total_preemptions.set(total_preemptions.get() + t_on.preemptions);
    });
    // The parity above is vacuous if no storm ever preempted: across the
    // randomized cases at least one eviction must actually have happened.
    assert!(total_preemptions.get() > 0, "no randomized storm ever forced a preemption");
}

#[test]
fn aging_bounds_background_wait_under_an_interactive_flood() {
    // Starvation bound, end to end, on the adversarial double bind: the
    // victim is both lowest-tier AND longest-prompt, under ShortestPrompt
    // admission, while short interactive work arrives every other step.
    // Waiting ticks promote it one rank per AGE_TICKS_PER_RANK, so it
    // must overtake fresh interactive arrivals and retire well before the
    // flood drains — un-aged, it would finish dead last.
    let m = tiny();
    let cfg = EngineConfig {
        slots: 1,
        prefill_chunk: 8,
        gen_tokens: 4,
        admission: AdmissionPolicy::ShortestPrompt,
        ..Default::default()
    };
    let n_flood = 24usize;
    let mut engine = Engine::new(Arc::clone(&m), cfg);
    let mut queue = Batcher::default();
    let mut finish_order = Vec::new();
    let mut step = 0usize;
    while finish_order.len() < n_flood + 1 {
        assert!(step < 10_000, "flood never drained");
        if step == 0 {
            let long: Vec<usize> = (0..10).map(|j| (j * 3) % 16).collect();
            queue.push(Request::new(0, long).with_priority(Priority::Background));
        }
        if step % 2 == 0 && step / 2 < n_flood {
            let id = 1 + (step / 2) as u64;
            queue.push(Request::new(id, vec![3, 5]).with_priority(Priority::Interactive));
        }
        for ev in engine.step(&mut queue) {
            if let SeqEvent::Finished(f) = ev {
                finish_order.push(f.id);
            }
        }
        step += 1;
    }
    let pos = finish_order.iter().position(|&id| id == 0).expect("background finished");
    // Service is ~5 steps per request on one slot. The background reaches
    // interactive rank after 2 × AGE_TICKS_PER_RANK = 32 waiting ticks and
    // beats same-rank two-token prompts one rank later (~48 ticks), so it
    // admits by roughly step 50 — about 10 interactives in. Anything in
    // the front half proves aging; dead last means starvation.
    assert!(
        pos < n_flood / 2,
        "aged background finished {pos} of {} — starved past the aging bound",
        finish_order.len()
    );
}

#[test]
fn shed_accounting_balances_and_spares_higher_tiers() {
    // SLO-aware shedding over a one-slot backlog: the predictor drops
    // exactly enough of the NEWEST lowest-tier queue to fit the SLO, every
    // dropped request reports Shed with no tokens, the interactive request
    // is never the victim, and the ledger balances: shed + joins covers
    // every request with joins == leaves (no preemption here).
    let m = tiny();
    let cfg = EngineConfig {
        slots: 1,
        prefill_chunk: 8,
        gen_tokens: 4,
        admission: AdmissionPolicy::Fcfs,
        slo_first_token_steps: 10,
        shed_policy: ShedPolicy::LowestPriority,
        ..Default::default()
    };
    let n_req = 12usize;
    let arrivals: Vec<(usize, Vec<usize>)> =
        (0..n_req).map(|i| (0usize, vec![(i * 3) % 16, 7])).collect();
    let (done, engine) = drive_with(&m, cfg, &arrivals, |id, p| {
        let tier = if id == 1 { Priority::Interactive } else { Priority::Background };
        Request::new(id, p).with_priority(tier)
    });
    let shed: Vec<u64> = done
        .values()
        .filter(|f| f.status == ResponseStatus::Shed)
        .map(|f| {
            assert!(f.tokens.is_empty(), "a shed request must not generate");
            f.id
        })
        .collect();
    assert!(!shed.is_empty(), "a 60-step backlog over a 10-step SLO must shed");
    assert!(shed.len() < n_req, "shedding must stop once the backlog fits");
    assert!(!shed.contains(&1), "the interactive request outranks every background");
    for f in done.values().filter(|f| f.status != ResponseStatus::Shed) {
        assert_eq!(f.status, ResponseStatus::Complete, "request {} had KV room", f.id);
        assert_eq!(f.tokens.len(), cfg.gen_tokens, "request {} had budget", f.id);
    }
    let t = engine.telemetry().lock().unwrap().clone();
    assert_eq!(t.shed, shed.len());
    assert_eq!(t.preemptions, 0);
    assert_eq!(t.joins, t.leaves, "every admission retired");
    assert_eq!(t.shed + t.joins, n_req, "every request either joined or shed, exactly once");
    assert!(t.slo_hits > 0, "the served stream kept its first-token SLO");
    assert_eq!(t.pages_in_use_now, 0);
}

#[test]
fn late_arrivals_join_mid_flight() {
    // A request arriving while a long sequence decodes must be served
    // before that sequence finishes (the defining continuous-batching
    // property: no wait-for-batch-drain).
    let m = tiny();
    let cfg = EngineConfig {
        slots: 2,
        prefill_chunk: 4,
        gen_tokens: 20,
        admission: AdmissionPolicy::Fcfs,
        ..Default::default()
    };
    let mut engine = Engine::new(Arc::clone(&m), cfg);
    let mut queue = Batcher::default();
    queue.push(Request::new(0, vec![1, 2, 3]));
    // Step a few times so the long sequence is mid-decode, then inject.
    let mut finished_order = Vec::new();
    for step in 0..10_000 {
        if step == 3 {
            queue.push(Request::new(1, vec![4, 5]));
        }
        for ev in engine.step(&mut queue) {
            if let SeqEvent::Finished(f) = ev {
                finished_order.push(f.id);
            }
        }
        if finished_order.len() == 2 {
            break;
        }
    }
    assert_eq!(finished_order.len(), 2, "both must finish");
    // Both ran concurrently: the late joiner decoded while seq 0 was still
    // resident, and outputs still match scalar decode exactly.
    let t = engine.telemetry().lock().unwrap().clone();
    assert!(
        t.occupancy.iter().any(|&o| o == 1.0),
        "late arrival never shared the arena: {:?}",
        t.occupancy
    );
}
