//! Integration tests across the AOT boundary: the JAX-lowered artifacts
//! executed through the PJRT runtime must agree with the native rust
//! implementations (architecture-parity contract).
//!
//! These tests self-skip when `artifacts/tiny` has not been built
//! (`make artifacts`), so `cargo test` stays green in a fresh checkout.

use oats::compress::oats::alternating_thresholding;
use oats::config::{ModelConfig, SparsityPattern};
use oats::data::{CorpusConfig, SyntheticCorpus};
use oats::model::{io, TransformerLM};
use oats::runtime::{self, Engine};
use oats::sparse::{Csr, LowRank, SparsePlusLowRank};
use oats::tensor::Matrix;
use oats::util::prng::Rng;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

fn engine_or_skip() -> Option<Engine> {
    let dir = artifacts_dir();
    if !Engine::available(&dir) {
        eprintln!("SKIP: artifacts/tiny not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(&dir).expect("engine"))
}

fn tiny_model(seed: u64) -> TransformerLM {
    TransformerLM::init(&ModelConfig::preset("tiny").unwrap(), seed)
}

fn run_lm_fwd(
    engine: &mut Engine,
    artifact: &str,
    model: &TransformerLM,
    tokens: &[Vec<usize>],
) -> Matrix {
    let tensors = io::flatten(model);
    let mut args = runtime::literals_from_tensors(&tensors).unwrap();
    args.push(runtime::literal_from_tokens(tokens).unwrap());
    let outs = engine.run(artifact, &args).unwrap();
    assert_eq!(outs.len(), 1);
    let (b, s) = (tokens.len(), tokens[0].len());
    runtime::matrix_from_literal(&outs[0], b * s, model.cfg.vocab).unwrap()
}

#[test]
fn lm_fwd_artifact_matches_native_forward() {
    let Some(mut engine) = engine_or_skip() else { return };
    let model = tiny_model(0xF00D);
    let cfg = engine.model_config().unwrap();
    assert_eq!(cfg.d_model, model.cfg.d_model);
    let batch = engine.train_batch().unwrap();
    let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, 7));
    let b = corpus.batch(batch, cfg.seq_len, &mut corpus.stream(1));

    let jax_logits = run_lm_fwd(&mut engine, "lm_fwd", &model, &b.inputs);
    let native = model.forward(&b.inputs);
    let rel = jax_logits.fro_dist(&native) / native.fro_norm();
    assert!(rel < 1e-3, "JAX/native logit divergence {rel}");
}

#[test]
fn pallas_attention_artifact_matches_ref_artifact() {
    let Some(mut engine) = engine_or_skip() else { return };
    let model = tiny_model(0xBEEF);
    let cfg = engine.model_config().unwrap();
    let batch = engine.train_batch().unwrap();
    let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, 8));
    let b = corpus.batch(batch, cfg.seq_len, &mut corpus.stream(2));

    let ref_logits = run_lm_fwd(&mut engine, "lm_fwd", &model, &b.inputs);
    let pallas_logits = run_lm_fwd(&mut engine, "lm_fwd_pallas", &model, &b.inputs);
    let rel = pallas_logits.fro_dist(&ref_logits) / ref_logits.fro_norm();
    assert!(rel < 1e-4, "pallas/ref divergence {rel}");
}

#[test]
fn train_step_artifact_decreases_loss() {
    let Some(engine) = engine_or_skip() else { return };
    let cfg = engine.model_config().unwrap();
    let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, 3));
    let mut trainer = oats::train::Trainer::new(engine, 42).unwrap();
    let curve = trainer.train(&corpus, 30).unwrap();
    let first = curve[..5].iter().sum::<f32>() / 5.0;
    let last = curve[curve.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(
        last < first - 0.1,
        "loss did not decrease: first≈{first:.3} last≈{last:.3}"
    );
    // Exported model evaluates consistently with the final loss.
    let model = trainer.to_model().unwrap();
    let b = corpus.batch(4, cfg.seq_len, &mut corpus.stream(99));
    let loss = model.loss(&b.inputs, &b.targets);
    assert!(loss < first as f64, "exported-model loss {loss} vs init {first}");
}

#[test]
fn oats_step_artifact_converges_and_matches_native_quality() {
    let Some(mut engine) = engine_or_skip() else { return };
    let p = engine.manifest.get("oats_step_params").expect("params").clone();
    let d = p.req_usize("dout").unwrap();
    let rank = p.req_usize("rank").unwrap();
    let k = p.req_usize("nonzeros").unwrap();

    let mut rng = Rng::new(5);
    let wd = Matrix::randn(d, d, 1.0, &mut rng);
    let mut s = Matrix::zeros(d, d);
    let omega = Matrix::randn(d, rank, 1.0, &mut rng);

    // Drive the artifact for 8 alternating iterations.
    let mut u = Matrix::zeros(d, rank);
    let mut vt = Matrix::zeros(rank, d);
    for _ in 0..8 {
        let args = vec![
            runtime::literal_from_matrix(&wd).unwrap(),
            runtime::literal_from_matrix(&s).unwrap(),
            runtime::literal_from_matrix(&omega).unwrap(),
        ];
        let outs = engine.run("oats_step", &args).unwrap();
        assert_eq!(outs.len(), 3);
        u = runtime::matrix_from_literal(&outs[0], d, rank).unwrap();
        vt = runtime::matrix_from_literal(&outs[1], rank, d).unwrap();
        s = runtime::matrix_from_literal(&outs[2], d, d).unwrap();
    }
    // Budget respected (rowwise ⌊k/d⌋ per row).
    assert_eq!(s.nnz(), (k / d) * d, "sparse budget");
    // Residual must be comparable to the native implementation's.
    let low = oats::tensor::matmul(&u, &vt);
    let mut resid = wd.clone();
    resid.axpy(-1.0, &s);
    resid.axpy(-1.0, &low);
    let jax_resid = resid.fro_norm();

    let mut rng2 = Rng::new(5);
    let native = alternating_thresholding(
        &wd, 8, rank, (k / d) * d, SparsityPattern::RowWise, false, None, &mut rng2,
    );
    assert!(
        jax_resid < native.residual * 1.15 + 1e-6,
        "artifact residual {jax_resid} vs native {}",
        native.residual
    );
}

#[test]
fn spl_matmul_artifact_matches_rust_kernel() {
    let Some(mut engine) = engine_or_skip() else { return };
    let sig = engine.manifest.get("artifacts").unwrap().get("spl_matmul").unwrap().clone();
    let ins = sig.get("inputs").unwrap().as_arr().unwrap();
    let shape = |i: usize| -> (usize, usize) {
        let s = ins[i].get("shape").unwrap().as_arr().unwrap();
        (s[0].as_usize().unwrap(), s[1].as_usize().unwrap())
    };
    let (bx, din) = shape(0);
    let (dout, _) = shape(1);
    let (_, r) = shape(2);

    let mut rng = Rng::new(11);
    let x = Matrix::randn(bx, din, 1.0, &mut rng);
    let mut s = Matrix::randn(dout, din, 1.0, &mut rng);
    for v in s.data.iter_mut() {
        if rng.f64() < 0.75 {
            *v = 0.0;
        }
    }
    let u = Matrix::randn(dout, r, 1.0, &mut rng);
    let vt = Matrix::randn(r, din, 1.0, &mut rng);

    let args = vec![
        runtime::literal_from_matrix(&x).unwrap(),
        runtime::literal_from_matrix(&s).unwrap(),
        runtime::literal_from_matrix(&u).unwrap(),
        runtime::literal_from_matrix(&vt).unwrap(),
    ];
    let outs = engine.run("spl_matmul", &args).unwrap();
    let jax_y = runtime::matrix_from_literal(&outs[0], bx, dout).unwrap();

    let spl = SparsePlusLowRank {
        sparse: Csr::from_dense(&s),
        low_rank: Some(LowRank { u, vt }),
    };
    let rust_y = spl.apply_batch(&x);
    let rel = jax_y.fro_dist(&rust_y) / rust_y.fro_norm();
    assert!(rel < 1e-4, "spl kernel divergence {rel}");
}

#[test]
fn lm_loss_artifact_matches_native_loss() {
    let Some(mut engine) = engine_or_skip() else { return };
    let model = tiny_model(0xCAFE);
    let cfg = engine.model_config().unwrap();
    let batch = engine.train_batch().unwrap();
    let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, 12));
    let b = corpus.batch(batch, cfg.seq_len, &mut corpus.stream(3));

    let tensors = io::flatten(&model);
    let mut args = runtime::literals_from_tensors(&tensors).unwrap();
    args.push(runtime::literal_from_tokens(&b.inputs).unwrap());
    args.push(runtime::literal_from_tokens(&b.targets).unwrap());
    let outs = engine.run("lm_loss", &args).unwrap();
    let jax_loss = runtime::f32_from_literal(&outs[0]).unwrap() as f64;
    let native_loss = model.loss(&b.inputs, &b.targets);
    assert!(
        (jax_loss - native_loss).abs() < 1e-3,
        "loss mismatch: jax {jax_loss} native {native_loss}"
    );
}
