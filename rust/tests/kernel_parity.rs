//! Kernel-parity contract: every sparse execution format (CSR, tiled BCSR,
//! packed N:M) and the fused sparse-plus-low-rank path must agree with the
//! dense reference to within 1e-4, across random shapes, sparsities, tile
//! geometries, batch sizes, and ranks. This is the gate that lets the
//! dispatch layer pick formats freely without touching model outputs.

use oats::compress::threshold::hard_threshold;
use oats::config::SparsityPattern;
use oats::sparse::{
    Bcsr, Csr, KernelChoice, LowRank, NmPacked, NmPattern, PackedLinear, SparsePlusLowRank,
};
use oats::tensor::{matmul_bt, matvec, Matrix};
use oats::util::prng::Rng;
use oats::util::prop::{check, random_sparse};

const TOL: f32 = 1e-4;

/// Per-element |a-b| ≤ TOL·max(1, |a|): absolute near zero, relative for
/// large magnitudes (accumulation order differs between kernels).
fn assert_close(label: &str, got: &Matrix, want: &Matrix) {
    assert_eq!((got.rows, got.cols), (want.rows, want.cols), "{label}: shape");
    for (i, (g, w)) in got.data.iter().zip(&want.data).enumerate() {
        let tol = TOL * w.abs().max(1.0);
        assert!(
            (g - w).abs() <= tol,
            "{label}: element {i} diverges: got {g}, want {w}"
        );
    }
}

#[test]
fn dense_csr_bcsr_batched_parity_prop() {
    check("dense == csr == bcsr (batched)", 40, |g| {
        let rows = g.usize_range(1, 180);
        let cols = g.usize_range(1, 180);
        let batch = g.usize_range(1, 12);
        let sparsity = g.f64_unit();
        let rt = *g.choose(&[1usize, 7, 64, 256]);
        let ct = *g.choose(&[8usize, 100, 512]);
        let mut rng = Rng::new(g.usize_range(0, 1 << 24) as u64);
        let w = random_sparse(rows, cols, sparsity, &mut rng);
        let x = Matrix::randn(batch, cols, 1.0, &mut rng);

        let want = matmul_bt(&x, &w);
        assert_close("csr", &Csr::from_dense(&w).matmul_xt(&x), &want);
        let bcsr = Bcsr::from_dense_tiled(&w, rt, ct);
        assert_close("bcsr", &bcsr.matmul_xt(&x), &want);
    });
}

#[test]
fn dense_csr_bcsr_matvec_parity_prop() {
    check("dense == csr == bcsr (matvec)", 40, |g| {
        let rows = g.usize_range(1, 200);
        let cols = g.usize_range(1, 200);
        let sparsity = g.f64_unit();
        let mut rng = Rng::new(g.usize_range(0, 1 << 24) as u64);
        let w = random_sparse(rows, cols, sparsity, &mut rng);
        let x = g.vec_normal(cols, 1.0);
        let want = matvec(&w, &x);

        let mut y = vec![0.0f32; rows];
        Csr::from_dense(&w).matvec(&x, &mut y);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() <= TOL * b.abs().max(1.0), "csr matvec: {a} vs {b}");
        }
        Bcsr::from_dense(&w).matvec(&x, &mut y);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() <= TOL * b.abs().max(1.0), "bcsr matvec: {a} vs {b}");
        }
    });
}

#[test]
fn fused_spl_parity_prop() {
    check("fused spl == dense(S + UVt)", 40, |g| {
        let rows = g.usize_range(2, 160);
        let cols = g.usize_range(2, 160);
        let batch = g.usize_range(1, 10);
        let rank = g.usize_range(1, 17);
        let sparsity = 0.3 + 0.65 * g.f64_unit();
        let mut rng = Rng::new(g.usize_range(0, 1 << 24) as u64);
        let s = random_sparse(rows, cols, sparsity, &mut rng);
        // Scaled-down factors keep |W| O(1) so the shared tolerance is fair.
        let spl = SparsePlusLowRank {
            sparse: Csr::from_dense(&s),
            low_rank: Some(LowRank {
                u: Matrix::randn(rows, rank, 0.3, &mut rng),
                vt: Matrix::randn(rank, cols, 0.3, &mut rng),
            }),
        };
        let x = Matrix::randn(batch, cols, 1.0, &mut rng);
        let want = matmul_bt(&x, &spl.to_dense());
        assert_close("spl fused", &spl.matmul_fused(&x), &want);
        assert_close("spl unfused", &spl.apply_batch(&x), &want);
    });
}

#[test]
fn nm_packed_parity_prop() {
    check("nm packed == dense", 30, |g| {
        let rows = g.usize_range(1, 80);
        let cols = g.usize_range(1, 120);
        let batch = g.usize_range(1, 8);
        let pat = *g.choose(&[NmPattern::TWO_FOUR, NmPattern::TWO_EIGHT]);
        let mut rng = Rng::new(g.usize_range(0, 1 << 24) as u64);
        let w = Matrix::randn(rows, cols, 1.0, &mut rng);
        let pruned = hard_threshold(&w, &w, 0, SparsityPattern::Nm { n: pat.n, m: pat.m });
        let packed = NmPacked::pack(&pruned, pat).expect("pruned layer satisfies pattern");
        let x = Matrix::randn(batch, cols, 1.0, &mut rng);
        assert_close("nm matmul_xt", &packed.matmul_xt(&x), &matmul_bt(&x, &pruned));

        let xv = g.vec_normal(cols, 1.0);
        let mut y = vec![0.0f32; rows];
        packed.matvec(&xv, &mut y);
        for (a, b) in y.iter().zip(&matvec(&pruned, &xv)) {
            assert!((a - b).abs() <= TOL * b.abs().max(1.0), "nm matvec: {a} vs {b}");
        }
    });
}

#[test]
fn packed_linear_parity_across_all_plans_prop() {
    // Whatever format the dispatch layer picks, the packed layer must match
    // the portable representation.
    check("packed linear == unpacked, any plan", 30, |g| {
        let rows = g.usize_range(2, 220);
        let cols = g.usize_range(2, 220);
        let batch = g.usize_range(1, 10);
        let sparsity = g.f64_unit();
        let with_lr = g.bool();
        let rank = g.usize_range(1, 9);
        let mut rng = Rng::new(g.usize_range(0, 1 << 24) as u64);
        let s = random_sparse(rows, cols, sparsity, &mut rng);
        let spl = SparsePlusLowRank {
            sparse: Csr::from_dense(&s),
            low_rank: with_lr.then(|| LowRank {
                u: Matrix::randn(rows, rank, 0.3, &mut rng),
                vt: Matrix::randn(rank, cols, 0.3, &mut rng),
            }),
        };
        let packed = PackedLinear::from_spl(&spl, batch);
        let x = Matrix::randn(batch, cols, 1.0, &mut rng);
        let want = matmul_bt(&x, &spl.to_dense());
        let label = format!("plan {}", packed.plan.choice.name());
        assert_close(&label, &packed.forward(&x), &want);

        let mut y = vec![0.0f32; rows];
        packed.forward_vec(x.row(0), &mut y);
        let mut want_v = vec![0.0f32; rows];
        spl.apply(x.row(0), &mut want_v);
        for (a, b) in y.iter().zip(&want_v) {
            assert!((a - b).abs() <= TOL * b.abs().max(1.0), "{label} vec: {a} vs {b}");
        }
    });
}

#[test]
fn dispatch_covers_every_kernel_family() {
    // Construct layers that should hit each plan branch, and verify parity
    // plus the expected choice.
    let mut rng = Rng::new(77);
    let b = 8;

    // Dense: 95% density.
    let w = random_sparse(200, 200, 0.05, &mut rng);
    let p = PackedLinear::from_csr(&Csr::from_dense(&w), b);
    assert_eq!(p.plan.choice, KernelChoice::Dense);

    // CSR: small sparse layer.
    let w = random_sparse(32, 32, 0.5, &mut rng);
    let p = PackedLinear::from_csr(&Csr::from_dense(&w), b);
    assert_eq!(p.plan.choice, KernelChoice::Csr);

    // BCSR: large unstructured-sparse layer.
    let w = random_sparse(256, 256, 0.5, &mut rng);
    let p = PackedLinear::from_csr(&Csr::from_dense(&w), b);
    assert_eq!(p.plan.choice, KernelChoice::Bcsr);

    // N:M: exactly 2:4-pruned layer.
    let w = Matrix::randn(128, 256, 1.0, &mut rng);
    let pruned = hard_threshold(&w, &w, 0, SparsityPattern::Nm { n: 2, m: 4 });
    let p = PackedLinear::from_csr(&Csr::from_dense(&pruned), b);
    assert_eq!(p.plan.choice, KernelChoice::Nm { n: 2, m: 4 });

    // All four parities on one shared input.
    for (label, w) in [
        ("dense-plan", random_sparse(200, 200, 0.05, &mut rng)),
        ("csr-plan", random_sparse(32, 32, 0.5, &mut rng)),
        ("bcsr-plan", random_sparse(256, 256, 0.5, &mut rng)),
    ] {
        let p = PackedLinear::from_csr(&Csr::from_dense(&w), b);
        let x = Matrix::randn(b, w.cols, 1.0, &mut rng);
        assert_close(label, &p.forward(&x), &matmul_bt(&x, &w));
    }
}
