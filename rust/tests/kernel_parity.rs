//! Kernel-parity contract: every sparse execution format (CSR, tiled BCSR,
//! packed N:M) and the fused sparse-plus-low-rank path must agree with the
//! dense reference to within 1e-4, across random shapes, sparsities, tile
//! geometries, batch sizes, and ranks. This is the gate that lets the
//! dispatch layer pick formats freely without touching model outputs.
//!
//! The i8-quantized tiles (QBcsr) carry a two-part contract instead: exact
//! (1e-4) parity against dense math on their own dequantized weights, plus
//! analytic quantization-error bounds against the original f32 weights.

use oats::compress::threshold::hard_threshold;
use oats::config::SparsityPattern;
use oats::sparse::microkernel::{self, with_isa, Isa};
use oats::sparse::{
    Bcsr, Csr, KernelChoice, LowRank, NmPacked, NmPattern, PackedLinear, SparsePlusLowRank,
};
use oats::sparse::{PackOptions, QBcsr};
use oats::tensor::{matmul_bt, matvec, Matrix};
use oats::util::prng::Rng;
use oats::util::prop::{check, random_sparse};

const TOL: f32 = 1e-4;

/// Per-element |a-b| ≤ TOL·max(1, |a|): absolute near zero, relative for
/// large magnitudes (accumulation order differs between kernels).
fn assert_close(label: &str, got: &Matrix, want: &Matrix) {
    assert_eq!((got.rows, got.cols), (want.rows, want.cols), "{label}: shape");
    for (i, (g, w)) in got.data.iter().zip(&want.data).enumerate() {
        let tol = TOL * w.abs().max(1.0);
        assert!(
            (g - w).abs() <= tol,
            "{label}: element {i} diverges: got {g}, want {w}"
        );
    }
}

#[test]
fn dense_csr_bcsr_batched_parity_prop() {
    check("dense == csr == bcsr (batched)", 40, |g| {
        let rows = g.usize_range(1, 180);
        let cols = g.usize_range(1, 180);
        let batch = g.usize_range(1, 12);
        let sparsity = g.f64_unit();
        let rt = *g.choose(&[1usize, 7, 64, 256]);
        let ct = *g.choose(&[8usize, 100, 512]);
        let mut rng = Rng::new(g.usize_range(0, 1 << 24) as u64);
        let w = random_sparse(rows, cols, sparsity, &mut rng);
        let x = Matrix::randn(batch, cols, 1.0, &mut rng);

        let want = matmul_bt(&x, &w);
        assert_close("csr", &Csr::from_dense(&w).matmul_xt(&x), &want);
        let bcsr = Bcsr::from_dense_tiled(&w, rt, ct);
        assert_close("bcsr", &bcsr.matmul_xt(&x), &want);
    });
}

#[test]
fn dense_csr_bcsr_matvec_parity_prop() {
    check("dense == csr == bcsr (matvec)", 40, |g| {
        let rows = g.usize_range(1, 200);
        let cols = g.usize_range(1, 200);
        let sparsity = g.f64_unit();
        let mut rng = Rng::new(g.usize_range(0, 1 << 24) as u64);
        let w = random_sparse(rows, cols, sparsity, &mut rng);
        let x = g.vec_normal(cols, 1.0);
        let want = matvec(&w, &x);

        let mut y = vec![0.0f32; rows];
        Csr::from_dense(&w).matvec(&x, &mut y);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() <= TOL * b.abs().max(1.0), "csr matvec: {a} vs {b}");
        }
        Bcsr::from_dense(&w).matvec(&x, &mut y);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() <= TOL * b.abs().max(1.0), "bcsr matvec: {a} vs {b}");
        }
    });
}

#[test]
fn fused_spl_parity_prop() {
    check("fused spl == dense(S + UVt)", 40, |g| {
        let rows = g.usize_range(2, 160);
        let cols = g.usize_range(2, 160);
        let batch = g.usize_range(1, 10);
        let rank = g.usize_range(1, 17);
        let sparsity = 0.3 + 0.65 * g.f64_unit();
        let mut rng = Rng::new(g.usize_range(0, 1 << 24) as u64);
        let s = random_sparse(rows, cols, sparsity, &mut rng);
        // Scaled-down factors keep |W| O(1) so the shared tolerance is fair.
        let spl = SparsePlusLowRank {
            sparse: Csr::from_dense(&s),
            low_rank: Some(LowRank {
                u: Matrix::randn(rows, rank, 0.3, &mut rng),
                vt: Matrix::randn(rank, cols, 0.3, &mut rng),
            }),
        };
        let x = Matrix::randn(batch, cols, 1.0, &mut rng);
        let want = matmul_bt(&x, &spl.to_dense());
        assert_close("spl fused", &spl.matmul_fused(&x), &want);
        assert_close("spl unfused", &spl.apply_batch(&x), &want);
    });
}

#[test]
fn qbcsr_parity_within_quantization_tolerance_prop() {
    // Two contracts for the i8 kernel. (1) Kernel exactness: it must
    // reproduce dense math on its OWN dequantized weights to the shared
    // kernel tolerance — quantization error lives in the weights, never in
    // the kernel. (2) Quantization tolerance vs the ORIGINAL weights:
    // symmetric i8 rounds each weight by at most half a step
    // (max|w| / 254), so per output element the error is bounded by
    // (max|w| / 254) · ‖x_row‖₁ (max-abs bound), and globally by a small
    // relative-Frobenius fraction for well-scaled weights.
    check("qbcsr ≈ dense within quant tolerance", 30, |g| {
        let rows = g.usize_range(1, 160);
        let cols = g.usize_range(1, 160);
        let batch = g.usize_range(1, 10);
        let sparsity = g.f64_unit();
        let rt = *g.choose(&[1usize, 8, 64]);
        let ct = *g.choose(&[8usize, 64, 512]);
        let mut rng = Rng::new(g.usize_range(0, 1 << 24) as u64);
        let w = random_sparse(rows, cols, sparsity, &mut rng);
        let x = Matrix::randn(batch, cols, 1.0, &mut rng);
        let q = QBcsr::quantize(&Bcsr::from_dense_tiled(&w, rt, ct));
        let got = q.matmul_xt(&x);

        // (1) exact kernel contract on dequantized weights.
        assert_close("qbcsr vs dequantized dense", &got, &matmul_bt(&x, &q.to_dense()));

        // (2a) max-abs quantization bound vs the original weights.
        let want = matmul_bt(&x, &w);
        let wmax = w.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        for bi in 0..batch {
            let l1: f32 = x.row(bi).iter().map(|v| v.abs()).sum();
            let bound = wmax / 254.0 * l1 + 1e-3;
            for (gv, wv) in got.row(bi).iter().zip(want.row(bi)) {
                assert!(
                    (gv - wv).abs() <= bound,
                    "qbcsr row {bi}: {gv} vs {wv} (bound {bound})"
                );
            }
        }
        // (2b) relative-Frobenius bound at layer-like sizes, where the
        // output norm concentrates (N(0,1) weights quantize to ~1%
        // relative error; 5% leaves ample margin). Tiny shapes can have a
        // near-zero output norm by chance and are already covered by the
        // rigorous max-abs bound above.
        if rows * cols >= 1024 {
            let dist = got.fro_dist(&want);
            assert!(
                dist <= 0.05 * want.fro_norm() + 1e-3,
                "qbcsr rel-frobenius drift: {dist} vs ‖want‖ {}",
                want.fro_norm()
            );
        }
    });
}

#[test]
fn bcsr_family_degenerate_cases() {
    // All-zero tiles, single-column tiles, and batch = 1, for both the f32
    // and the i8 tile formats.
    let mut rng = Rng::new(31);

    // All-zero matrix (every tile empty).
    let z = Matrix::zeros(100, 90);
    let x1 = Matrix::randn(1, 90, 1.0, &mut rng);
    let bz = Bcsr::from_dense_tiled(&z, 16, 8);
    let qz = QBcsr::quantize(&bz);
    assert_eq!(bz.matmul_xt(&x1), Matrix::zeros(1, 100));
    assert_eq!(qz.matmul_xt(&x1), Matrix::zeros(1, 100));
    assert_eq!(qz.nnz(), 0);
    assert_eq!(qz.max_tile_rel_error(), 0.0);

    // Mostly-empty tiling: nonzeros confined to the top-left 32×32 corner
    // of a 128×128 matrix under 64×64 tiles — three of four tiles empty.
    let mut corner = Matrix::zeros(128, 128);
    for r in 0..32 {
        for c in 0..32 {
            if (r + c) % 3 != 0 {
                *corner.at_mut(r, c) = rng.normal();
            }
        }
    }
    let bc = Bcsr::from_dense_tiled(&corner, 64, 64);
    let qc = QBcsr::quantize(&bc);
    for batch in [1usize, 5] {
        let x = Matrix::randn(batch, 128, 1.0, &mut rng);
        let want = matmul_bt(&x, &corner);
        assert_close("bcsr corner", &bc.matmul_xt(&x), &want);
        assert_close("qbcsr corner", &qc.matmul_xt(&x), &matmul_bt(&x, &qc.to_dense()));
    }

    // Single-column tiles (col_tile = 1) and a single-column matrix.
    let skinny = random_sparse(40, 1, 0.4, &mut rng);
    let wide = random_sparse(30, 50, 0.5, &mut rng);
    for (label, m, ct) in [("1-col matrix", &skinny, 1usize), ("1-col tiles", &wide, 1)] {
        let b = Bcsr::from_dense_tiled(m, 4, ct);
        let q = QBcsr::quantize(&b);
        for batch in [1usize, 3] {
            let x = Matrix::randn(batch, m.cols, 1.0, &mut rng);
            assert_close(label, &b.matmul_xt(&x), &matmul_bt(&x, m));
            assert_close(label, &q.matmul_xt(&x), &matmul_bt(&x, &q.to_dense()));
        }
        let xv: Vec<f32> = (0..m.cols).map(|i| (i as f32).cos()).collect();
        let mut y1 = vec![0.0f32; m.rows];
        let mut y2 = vec![0.0f32; m.rows];
        b.matvec(&xv, &mut y1);
        q.matvec(&xv, &mut y2);
        let want_b = matvec(m, &xv);
        let want_q = matvec(&q.to_dense(), &xv);
        for ((a, wb), (bq, wq)) in y1.iter().zip(&want_b).zip(y2.iter().zip(&want_q)) {
            assert!((a - wb).abs() <= TOL * wb.abs().max(1.0), "{label} f32: {a} vs {wb}");
            assert!((bq - wq).abs() <= TOL * wq.abs().max(1.0), "{label} i8: {bq} vs {wq}");
        }
    }
}

#[test]
fn quantized_packed_linear_respects_error_gate() {
    // The dispatch layer's accuracy arbitration, end to end: well-behaved
    // weights upgrade to i8 tiles; an outlier-dominated tile trips the
    // per-tile gate and the plan falls back to f32 BCSR.
    let mut rng = Rng::new(77);
    let w = random_sparse(128, 256, 0.45, &mut rng);
    let p = PackedLinear::from_csr_with(&Csr::from_dense(&w), &PackOptions::quantized(8));
    assert_eq!(p.plan.choice, KernelChoice::QBcsr);
    let x = Matrix::randn(8, 256, 1.0, &mut rng);
    assert_close("qbcsr packed", &p.forward(&x), &matmul_bt(&x, &p.to_dense()));

    let outlier = oats::util::prop::outlier_dominated(128, 256);
    let g = PackedLinear::from_csr_with(&Csr::from_dense(&outlier), &PackOptions::quantized(8));
    assert_eq!(g.plan.choice, KernelChoice::Bcsr, "gate must reject outlier tiles");
    assert_close("gated f32 fallback", &g.forward(&x), &matmul_bt(&x, &outlier));
}

/// The fixtures the microkernel-specific tests below share: one weight in
/// all four packed formats plus an exactly-2:4-pruned sibling.
fn microkernel_fixtures(rng: &mut Rng) -> (Matrix, Bcsr, QBcsr, Csr, Matrix, NmPacked) {
    let w = random_sparse(96, 88, 0.55, rng);
    let bcsr = Bcsr::from_dense_tiled(&w, 16, 32);
    let qbcsr = QBcsr::quantize(&bcsr);
    let csr = Csr::from_dense(&w);
    let nm_dense = Matrix::randn(96, 88, 1.0, rng);
    let nm_pruned = hard_threshold(&nm_dense, &nm_dense, 0, SparsityPattern::Nm { n: 2, m: 4 });
    let nm = NmPacked::pack(&nm_pruned, NmPattern::TWO_FOUR).expect("2:4-pruned validates");
    (w, bcsr, qbcsr, csr, nm_pruned, nm)
}

#[test]
fn microkernel_every_lane_tail_split_matches_dense() {
    // Batch widths 1..=17 cover every register-lane decomposition of the
    // b-wide fold: pure scalar (1..=3), one 4-lane (4), 8-lane (8),
    // 16-lane (16), and every mixed lane+tail split in between (e.g.
    // 15 = 8+4+1+1+1, 17 = 16+1) — for all four formats.
    let mut rng = Rng::new(2024);
    let (w, bcsr, qbcsr, csr, nm_pruned, nm) = microkernel_fixtures(&mut rng);
    for b in 1..=17 {
        let x = Matrix::randn(b, w.cols, 1.0, &mut rng);
        let want = matmul_bt(&x, &w);
        assert_close(&format!("bcsr b={b}"), &bcsr.matmul_xt(&x), &want);
        assert_close(&format!("csr b={b}"), &csr.matmul_xt(&x), &want);
        let qwant = matmul_bt(&x, &qbcsr.to_dense());
        assert_close(&format!("qbcsr b={b}"), &qbcsr.matmul_xt(&x), &qwant);
        assert_close(&format!("nm b={b}"), &nm.matmul_xt(&x), &matmul_bt(&x, &nm_pruned));
    }
}

#[test]
fn simd_dispatch_is_bit_identical_to_generic_path() {
    // The target_feature clones only widen vectors — the operation
    // sequence per output element is identical, so the dispatched result
    // must equal the forced-generic result BIT FOR BIT, for every format
    // and the fused sparse-plus-low-rank path. (On hosts without AVX2 both
    // sides run the generic build and the assertion is trivially true.)
    println!("dispatch under test: {}", microkernel::detected_isa().name());
    let mut rng = Rng::new(77);
    let (w, bcsr, qbcsr, csr, _nm_pruned, nm) = microkernel_fixtures(&mut rng);
    let spl = SparsePlusLowRank {
        sparse: Csr::from_dense(&w),
        low_rank: Some(LowRank {
            u: Matrix::randn(96, 6, 0.3, &mut rng),
            vt: Matrix::randn(6, 88, 0.3, &mut rng),
        }),
    };
    let packed = PackedLinear::from_spl(&spl, 9);
    let labels = ["bcsr", "csr", "qbcsr", "nm", "fused"];
    for b in [1usize, 5, 8, 13, 16, 17] {
        let x = Matrix::randn(b, w.cols, 1.0, &mut rng);
        let all = || {
            [
                bcsr.matmul_xt(&x),
                csr.matmul_xt(&x),
                qbcsr.matmul_xt(&x),
                nm.matmul_xt(&x),
                packed.forward(&x),
            ]
        };
        let fast = all();
        let slow = with_isa(Isa::Generic, all);
        for ((f, s), label) in fast.iter().zip(&slow).zip(labels) {
            assert_eq!(f, s, "{label} b={b}: SIMD dispatch must be bit-identical");
        }
    }
}

#[test]
fn batch_width_never_changes_a_columns_result() {
    // The numerics-invariance contract the serve engine's lockstep
    // bit-identity properties rest on: laning is across batch columns and
    // each output element folds its nonzeros in index order, so a given
    // input column's output is BIT-identical no matter how many other
    // columns share the batch (and therefore which lane width covers it).
    let mut rng = Rng::new(4242);
    let (w, bcsr, qbcsr, csr, _nm_pruned, nm) = microkernel_fixtures(&mut rng);
    let lr = LowRank {
        u: Matrix::randn(96, 5, 0.3, &mut rng),
        vt: Matrix::randn(5, 88, 0.3, &mut rng),
    };
    let spl = SparsePlusLowRank { sparse: Csr::from_dense(&w), low_rank: Some(lr) };
    let packed = PackedLinear::from_spl(&spl, 9);
    let x0: Vec<f32> = (0..w.cols).map(|i| (i as f32 * 0.37).sin()).collect();
    let x1 = Matrix::from_vec(1, w.cols, x0.clone());
    let base = [
        bcsr.matmul_xt(&x1),
        qbcsr.matmul_xt(&x1),
        csr.matmul_xt(&x1),
        nm.matmul_xt(&x1),
        packed.forward(&x1),
    ];
    for b in 2..=17 {
        let mut x = Matrix::randn(b, w.cols, 1.0, &mut rng);
        x.row_mut(0).copy_from_slice(&x0);
        let got = [
            bcsr.matmul_xt(&x),
            qbcsr.matmul_xt(&x),
            csr.matmul_xt(&x),
            nm.matmul_xt(&x),
            packed.forward(&x),
        ];
        let labels = ["bcsr", "qbcsr", "csr", "nm", "fused"];
        for ((g, want), label) in got.iter().zip(&base).zip(labels) {
            assert_eq!(g.row(0), want.row(0), "{label}: batch width {b} changed column 0");
        }
    }
}

#[test]
fn empty_tiles_and_rows_fuse_cleanly_with_low_rank() {
    // An all-zero sparse term walked through the engine must still produce
    // exactly the low-rank contribution (empty tiles/rows are skipped, the
    // fused pass writes every output element once), across lane splits.
    let mut rng = Rng::new(55);
    let z = Matrix::zeros(128, 96);
    let lr = LowRank {
        u: Matrix::randn(128, 4, 0.5, &mut rng),
        vt: Matrix::randn(4, 96, 0.5, &mut rng),
    };
    let spl = SparsePlusLowRank { sparse: Csr::from_dense(&z), low_rank: Some(lr.clone()) };
    for b in [1usize, 7, 16] {
        let x = Matrix::randn(b, 96, 1.0, &mut rng);
        let mut want = Matrix::zeros(b, 128);
        lr.apply_batch_accumulate(&x, &mut want);
        assert_close(&format!("zero sparse + lr b={b}"), &spl.matmul_fused(&x), &want);
    }
    // And a partially-empty tiling: nonzeros confined to rows 0..8 of a
    // 128-row matrix under 64-row tiles leaves whole row tiles empty.
    let mut m = Matrix::zeros(128, 96);
    for r in 0..8 {
        for c in 0..96 {
            if (r * 7 + c) % 3 == 0 {
                *m.at_mut(r, c) = rng.normal();
            }
        }
    }
    let bc = Bcsr::from_dense_tiled(&m, 64, 64);
    for b in [1usize, 9] {
        let x = Matrix::randn(b, 96, 1.0, &mut rng);
        assert_close(&format!("empty row tiles b={b}"), &bc.matmul_xt(&x), &matmul_bt(&x, &m));
    }
}

#[test]
fn nm_packed_parity_prop() {
    check("nm packed == dense", 30, |g| {
        let rows = g.usize_range(1, 80);
        let cols = g.usize_range(1, 120);
        let batch = g.usize_range(1, 8);
        let pat = *g.choose(&[NmPattern::TWO_FOUR, NmPattern::TWO_EIGHT]);
        let mut rng = Rng::new(g.usize_range(0, 1 << 24) as u64);
        let w = Matrix::randn(rows, cols, 1.0, &mut rng);
        let pruned = hard_threshold(&w, &w, 0, SparsityPattern::Nm { n: pat.n, m: pat.m });
        let packed = NmPacked::pack(&pruned, pat).expect("pruned layer satisfies pattern");
        let x = Matrix::randn(batch, cols, 1.0, &mut rng);
        assert_close("nm matmul_xt", &packed.matmul_xt(&x), &matmul_bt(&x, &pruned));

        let xv = g.vec_normal(cols, 1.0);
        let mut y = vec![0.0f32; rows];
        packed.matvec(&xv, &mut y);
        for (a, b) in y.iter().zip(&matvec(&pruned, &xv)) {
            assert!((a - b).abs() <= TOL * b.abs().max(1.0), "nm matvec: {a} vs {b}");
        }
    });
}

#[test]
fn packed_linear_parity_across_all_plans_prop() {
    // Whatever format the dispatch layer picks, the packed layer must match
    // the portable representation.
    check("packed linear == unpacked, any plan", 30, |g| {
        let rows = g.usize_range(2, 220);
        let cols = g.usize_range(2, 220);
        let batch = g.usize_range(1, 10);
        let sparsity = g.f64_unit();
        let with_lr = g.bool();
        let rank = g.usize_range(1, 9);
        let mut rng = Rng::new(g.usize_range(0, 1 << 24) as u64);
        let s = random_sparse(rows, cols, sparsity, &mut rng);
        let spl = SparsePlusLowRank {
            sparse: Csr::from_dense(&s),
            low_rank: with_lr.then(|| LowRank {
                u: Matrix::randn(rows, rank, 0.3, &mut rng),
                vt: Matrix::randn(rank, cols, 0.3, &mut rng),
            }),
        };
        let packed = PackedLinear::from_spl(&spl, batch);
        let x = Matrix::randn(batch, cols, 1.0, &mut rng);
        let want = matmul_bt(&x, &spl.to_dense());
        let label = format!("plan {}", packed.plan.choice.name());
        assert_close(&label, &packed.forward(&x), &want);

        let mut y = vec![0.0f32; rows];
        packed.forward_vec(x.row(0), &mut y);
        let mut want_v = vec![0.0f32; rows];
        spl.apply(x.row(0), &mut want_v);
        for (a, b) in y.iter().zip(&want_v) {
            assert!((a - b).abs() <= TOL * b.abs().max(1.0), "{label} vec: {a} vs {b}");
        }
    });
}

#[test]
fn dispatch_covers_every_kernel_family() {
    // Construct layers that should hit each plan branch, and verify parity
    // plus the expected choice.
    let mut rng = Rng::new(77);
    let b = 8;

    // Dense: 95% density.
    let w = random_sparse(200, 200, 0.05, &mut rng);
    let p = PackedLinear::from_csr(&Csr::from_dense(&w), b);
    assert_eq!(p.plan.choice, KernelChoice::Dense);

    // CSR: small sparse layer.
    let w = random_sparse(32, 32, 0.5, &mut rng);
    let p = PackedLinear::from_csr(&Csr::from_dense(&w), b);
    assert_eq!(p.plan.choice, KernelChoice::Csr);

    // BCSR: large unstructured-sparse layer.
    let w = random_sparse(256, 256, 0.5, &mut rng);
    let p = PackedLinear::from_csr(&Csr::from_dense(&w), b);
    assert_eq!(p.plan.choice, KernelChoice::Bcsr);

    // N:M: exactly 2:4-pruned layer.
    let w = Matrix::randn(128, 256, 1.0, &mut rng);
    let pruned = hard_threshold(&w, &w, 0, SparsityPattern::Nm { n: 2, m: 4 });
    let p = PackedLinear::from_csr(&Csr::from_dense(&pruned), b);
    assert_eq!(p.plan.choice, KernelChoice::Nm { n: 2, m: 4 });

    // All four parities on one shared input.
    for (label, w) in [
        ("dense-plan", random_sparse(200, 200, 0.05, &mut rng)),
        ("csr-plan", random_sparse(32, 32, 0.5, &mut rng)),
        ("bcsr-plan", random_sparse(256, 256, 0.5, &mut rng)),
    ] {
        let p = PackedLinear::from_csr(&Csr::from_dense(&w), b);
        let x = Matrix::randn(b, w.cols, 1.0, &mut rng);
        assert_close(label, &p.forward(&x), &matmul_bt(&x, &w));
    }
}
