#!/usr/bin/env python3
"""Trace gate: well-formedness, span balance, and per-request completeness.

Validates the Chrome trace-event JSON (schema ``oats-trace-v1``) written by
``oats serve-load --trace`` and the bench harness:

* **Well-formedness**: the schema marker is present, ``traceEvents`` is a
  non-empty array, every event carries name/ph/ts/pid/tid, phases are
  limited to the ones the recorder emits (``X`` complete spans, ``i``
  instants, ``C`` counters), and timestamps/durations are non-negative.
* **Span balance**: within one (pid, tid) track, complete spans must nest
  — a span may not straddle the boundary of the span enclosing it. The
  recorder's RAII guards guarantee this by construction, so a violation
  means clock or export corruption.
* **Request completeness**: lifecycle instants grouped by their ``id``
  argument must form ordered chains (enqueued <= admitted <= first_token
  <= retired), and at least ``--min-chains`` chains must be complete.
* **Preemption lifecycle**: any request that was preempted must show the
  full eviction round trip in order (admitted <= preempt <= requeue <=
  readmit_recompute <= retired); ``--min-preempted`` (CI sets it on the
  overload run) requires that many such complete chains, proving the storm
  actually forced eviction and the victims recovered.

``droppedEvents > 0`` is reported as a warning, not a failure: the ring
drops newest-first under overload by design, and a partially-dropped trace
is still loadable.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

SCHEMA = "oats-trace-v1"
PH_ALLOWED = ("X", "i", "C")
# Nesting slack in microseconds: timestamps are ns-precise but exported as
# fractional-us floats, so boundaries can wobble by well under a ns.
EPS = 1e-3
LIFECYCLE = ("request_enqueued", "request_admitted", "request_first_token", "request_retired")
# Instants an eviction round trip adds to a victim's chain, in order.
PREEMPTION = ("preempt", "requeue", "readmit_recompute")


def check_events(name, events):
    """Per-event well-formedness errors."""
    errs = []
    for i, ev in enumerate(events):
        missing = [k for k in ("name", "ph", "ts", "pid", "tid") if k not in ev]
        if missing:
            errs.append(f"{name}: event {i} missing {missing}")
            continue
        ph = ev["ph"]
        if ph not in PH_ALLOWED:
            errs.append(f"{name}: event {i} ({ev['name']}) has unknown phase {ph!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            errs.append(f"{name}: event {i} ({ev['name']}) has bad ts {ev['ts']!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"{name}: span {ev['name']} has bad dur {dur!r}")
    return errs


def check_span_nesting(name, events):
    """Spans within one (pid, tid) track must nest, never straddle."""
    errs = []
    tracks = {}
    for ev in events:
        if ev.get("ph") == "X" and isinstance(ev.get("dur"), (int, float)):
            key = (ev["pid"], ev["tid"])
            tracks.setdefault(key, []).append((ev["ts"], ev["dur"], ev["name"]))
    for key, spans in sorted(tracks.items()):
        # Sort outermost-first at equal start so enclosers are pushed first.
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack = []
        for ts, dur, span_name in spans:
            while stack and ts >= stack[-1][0] + stack[-1][1] - EPS:
                stack.pop()
            if stack and ts + dur > stack[-1][0] + stack[-1][1] + EPS:
                errs.append(
                    f"{name}: span {span_name} [{ts}, {ts + dur}] straddles "
                    f"enclosing {stack[-1][2]} on track {key}"
                )
            stack.append((ts, dur, span_name))
    return errs


def lifecycle_chains(events):
    """{request id: {instant name: first ts}} for the lifecycle instants."""
    chains = {}
    for ev in events:
        if ev.get("ph") != "i" or ev.get("name") not in LIFECYCLE + PREEMPTION:
            continue
        rid = ev.get("args", {}).get("id")
        if rid is None:
            continue
        chains.setdefault(rid, {}).setdefault(ev["name"], ev["ts"])
    return chains


def check_chains(name, chains, min_chains):
    """Ordering and completeness errors for the per-request chains."""
    errs = []
    complete = 0
    for rid, chain in sorted(chains.items()):
        enq, adm, ft, ret = (chain.get(k) for k in LIFECYCLE)
        if enq is None or ret is None:
            errs.append(f"{name}: request {rid:g} chain lacks enqueued/retired")
            continue
        if enq > ret + EPS:
            errs.append(f"{name}: request {rid:g} retired ({ret}) before enqueued ({enq})")
        if adm is not None and not enq - EPS <= adm <= ret + EPS:
            errs.append(f"{name}: request {rid:g} admission {adm} outside [{enq}, {ret}]")
        if ft is not None:
            if adm is None:
                errs.append(f"{name}: request {rid:g} has a first token but no admission")
            elif not adm - EPS <= ft <= ret + EPS:
                errs.append(f"{name}: request {rid:g} first token {ft} outside [{adm}, {ret}]")
        if adm is not None and ft is not None:
            complete += 1
    if complete < min_chains:
        errs.append(
            f"{name}: only {complete} complete request chains "
            f"(enqueued through retired), expected >= {min_chains}"
        )
    return errs, complete


def check_preempt_chains(name, chains, min_preempted):
    """Eviction round trips must be ordered and, under ``--min-preempted``,
    present: admitted <= preempt <= requeue <= readmit_recompute <= retired.
    """
    errs = []
    complete = 0
    for rid, chain in sorted(chains.items()):
        pre, req, rea = (chain.get(k) for k in PREEMPTION)
        if pre is None and req is None and rea is None:
            continue
        _, adm, _, ret = (chain.get(k) for k in LIFECYCLE)
        if pre is None or req is None:
            errs.append(f"{name}: request {rid:g} has a partial preempt/requeue pair")
            continue
        if adm is None or not adm - EPS <= pre:
            errs.append(f"{name}: request {rid:g} preempted ({pre}) before admission ({adm})")
        if pre > req + EPS:
            errs.append(f"{name}: request {rid:g} requeued ({req}) before preempt ({pre})")
        # A victim resolved slot-free at readmission (its stream already
        # fills capacity) legitimately never recomputes; otherwise the
        # readmission must recompute, inside the requeue..retired window.
        if rea is not None:
            if not req - EPS <= rea:
                errs.append(f"{name}: request {rid:g} readmitted ({rea}) before requeue ({req})")
            if ret is not None and rea > ret + EPS:
                errs.append(f"{name}: request {rid:g} readmitted ({rea}) after retire ({ret})")
            if ret is not None:
                complete += 1
    if complete < min_preempted:
        errs.append(
            f"{name}: only {complete} complete preemption chains "
            f"(admitted through readmit_recompute to retired), expected >= {min_preempted}"
        )
    return errs, complete


def check_trace(name, doc, min_chains, min_preempted=0):
    """(errors, summary line) for one parsed trace document."""
    if doc.get("schema") != SCHEMA:
        return [f"{name}: unexpected schema {doc.get('schema')!r}"], ""
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{name}: traceEvents missing or empty"], ""
    errs = check_events(name, events)
    if errs:
        # Malformed events would make the structural checks misfire.
        return errs, ""
    errs.extend(check_span_nesting(name, events))
    chains = lifecycle_chains(events)
    chain_errs, complete = check_chains(name, chains, min_chains)
    errs.extend(chain_errs)
    preempt_errs, preempted = check_preempt_chains(name, chains, min_preempted)
    errs.extend(preempt_errs)
    spans = sum(1 for ev in events if ev["ph"] == "X")
    dropped = doc.get("droppedEvents", 0)
    summary = (
        f"{name}: {len(events)} events ({spans} spans), "
        f"{complete}/{len(chains)} complete request chains, "
        f"{preempted} preemption round trips, {dropped} dropped"
    )
    if dropped:
        summary += " [warning: ring overflowed; trace is partial]"
    return errs, summary


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+", help="trace JSON files to validate")
    ap.add_argument(
        "--min-chains",
        type=int,
        default=1,
        help="minimum complete request lifecycle chains per trace",
    )
    ap.add_argument(
        "--min-preempted",
        type=int,
        default=0,
        help="minimum complete preemption round trips per trace (overload CI sets this)",
    )
    args = ap.parse_args(argv)

    failed = []
    for path in args.paths:
        name = os.path.basename(path)
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError) as e:
            failed.append(f"{name}: unreadable ({e})")
            continue
        errs, summary = check_trace(name, doc, args.min_chains, args.min_preempted)
        if summary:
            print(summary)
        failed.extend(errs)
    print(f"trace gate: {len(args.paths)} traces checked")
    if failed:
        print("trace gate failed:\n" + "\n".join(failed), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
