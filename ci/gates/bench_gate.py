#!/usr/bin/env python3
"""Bench perf gate: fixed floors plus a rolling-median trend ratchet.

Reads the ``BENCH_*.json`` files emitted by ``cargo bench --bench micro``
(via ``OATS_BENCH_DIR``) and gates the csr->bcsr, bcsr->qbcsr, and
SIMD-dispatch-vs-generic speedup comparisons.

Two kinds of floors apply to every comparison:

* **Fixed floors** (``FLOORS``): conservative "not catastrophically
  regressed" bounds. Quick-mode timings on shared CI runners are noisy, so
  these sit well below locally-measured speedups; the simd-vs-generic
  floors sit below 1.0x because a host without AVX2 runs identical code on
  both sides.
* **Trend ratchet**: when ``ci/bench_history.jsonl`` carries history for a
  comparison, the effective floor is raised to ``RATCHET_FRACTION`` x the
  rolling median of the last ``HISTORY_WINDOW`` recorded ratios. A change
  that halves a speedup the suite historically sustained fails even if it
  still clears the fixed floor.

Updating the history (maintainers, on a quiet machine)::

    OATS_BENCH_DIR=bench-out cargo bench --bench micro
    python3 ci/gates/bench_gate.py --bench-dir bench-out --append --note "$(hostname)"
    git add ci/bench_history.jsonl   # commit alongside the perf change

CI never appends — the committed history is the reference, so a PR that
regresses performance cannot also lower its own bar.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from statistics import median

# Fixed floors, keyed by comparison-label prefix. ``trace_overhead``
# compares the fused kernel with the trace recorder off vs on; 0.8 means
# a traced dispatch may cost at most 25% on this noisy quick-mode path
# (the recorder's steady-state overhead is well under 5% locally).
FLOORS = {
    "bcsr_vs_csr": 0.7,
    "qbcsr_vs_bcsr": 0.5,
    "bcsr_simd_vs_generic": 0.7,
    "fused_simd_vs_generic": 0.7,
    "trace_overhead": 0.8,
    # Dense GEMM in the sliced shape vs the full shape: the sliced side
    # does strictly less work, so 0.7 only catches a dispatch catastrophe
    # (e.g. the sliced layer falling off the packed fast path).
    "sliced_vs_dense": 0.7,
}

# The ratchet trips at this fraction of the rolling median: loose enough to
# absorb runner noise, tight enough to catch a halved speedup.
RATCHET_FRACTION = 0.5
HISTORY_WINDOW = 20

DEFAULT_HISTORY = os.path.join(os.path.dirname(__file__), "..", "bench_history.jsonl")


def load_comparisons(bench_dir):
    """All (label, speedup) comparison rows across ``BENCH_*.json`` files."""
    rows = []
    for path in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
        with open(path) as f:
            doc = json.load(f)
        for c in doc.get("comparisons", []):
            rows.append((c["label"], float(c["speedup"])))
    return rows


def read_history(path):
    """History entries (one JSON object per line), oldest first."""
    if not os.path.exists(path):
        return []
    entries = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                entries.append(json.loads(line))
    return entries


def history_ratios(entries, prefix):
    """Recorded ratios for one comparison prefix, oldest first."""
    return [e["ratios"][prefix] for e in entries if prefix in e.get("ratios", {})]


def effective_floor(prefix, entries):
    """Fixed floor raised by the rolling-median ratchet when history exists."""
    floor = FLOORS[prefix]
    ratios = history_ratios(entries, prefix)[-HISTORY_WINDOW:]
    if ratios:
        floor = max(floor, RATCHET_FRACTION * median(ratios))
    return floor


def gate(comparisons, entries):
    """Apply the floors; returns (ok_lines, fail_lines, ratios_by_prefix)."""
    ok, failed, ratios = [], [], {}
    for label, speedup in comparisons:
        for prefix in FLOORS:
            if label.startswith(prefix):
                ratios.setdefault(prefix, speedup)
                floor = effective_floor(prefix, entries)
                line = f"{label}: {speedup:.2f}x (floor {floor:.2f}x)"
                (failed if speedup < floor else ok).append(line)
    return ok, failed, ratios


def append_history(path, ratios, note):
    entry = {"ratios": ratios}
    if note:
        entry["note"] = note
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench-dir", default="bench-out")
    ap.add_argument("--history", default=os.path.normpath(DEFAULT_HISTORY))
    ap.add_argument(
        "--append",
        action="store_true",
        help="record this run's ratios into the history (local use only)",
    )
    ap.add_argument("--note", default="", help="free-form provenance for --append")
    args = ap.parse_args(argv)

    comparisons = load_comparisons(args.bench_dir)
    entries = read_history(args.history)
    ok, failed, ratios = gate(comparisons, entries)
    if not ratios:
        print(f"perf gate: no gated comparisons found in {args.bench_dir}", file=sys.stderr)
        return 1
    for line in ok:
        print(f"ok  {line}")
    print(f"perf gate: {len(ok) + len(failed)} comparisons checked against {len(entries)} history entries")
    if failed:
        print("perf gate failed:\n" + "\n".join(failed), file=sys.stderr)
        return 1
    if args.append:
        append_history(args.history, ratios, args.note)
        print(f"appended ratios for {sorted(ratios)} to {args.history}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
