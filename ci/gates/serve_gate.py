#!/usr/bin/env python3
"""Serve smoke gate: telemetry, paged-arena accounting, and prefix reuse.

Reads the ``SERVE_*.json`` (schema ``oats-serve-v1``) files that
``oats serve-load`` emits into ``$OATS_BENCH_DIR`` and applies three layers
of checks:

* **Per-run**: the engine actually served (tokens/s > 0), the
  continuous-batching telemetry is present and consistent (joins == leaves
  > 0, occupancies in (0, 1], ordered latency percentiles, the decode
  workspace warmed), the non-Complete statuses were exercised (serve-load
  always submits one oversized and one exactly-at-capacity prompt), the
  paged arena leaked zero pages at drain, the queue-wait summary covers
  every request, and the per-phase breakdown (admit/prefill/decode/retire)
  sums to no more than the step wall-clock.
* **Whole-vs-paged pair**: at equal ``kv_arena_bytes``, the paged arena
  must decode wider than the whole-cache arena (peak decode batch).
* **Shared-vs-unshared pair**: the ``--shared-prefix`` run must have
  actually reused KV (``prefill_tokens_saved > 0``, ``shared_pages > 0``)
  at equal ``kv_arena_bytes``, and its ``completions_digest`` must equal
  the ``--no-share-prefix`` run's byte for byte — prefix sharing is an
  optimization, never a behaviour.
* **Overload trio** (``--require-overload``): the burst-arrival overload
  run must actually preempt (``preemptions > 0``) and shed (``shed > 0``)
  while keeping ``goodput_under_slo > 0`` and interactive first-token p99
  no worse than batch p99; the storm A/B pair (shedding off, preemption
  toggled) must be ``completions_digest``-equal at equal arena bytes —
  preemption is scheduling, never behaviour.

Runs are matched to roles by the tag embedded in the filename
(``SERVE_<tag>.json``); the whole-cache run is the one carrying none of the
special tags.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def check_run(name, doc):
    """Per-run errors for one SERVE_*.json document."""
    errs = []

    def bad(msg):
        errs.append(f"{name}: {msg}")

    if doc.get("schema") != "oats-serve-v1":
        bad(f"unexpected schema {doc.get('schema')!r}")
        return errs
    if doc["tokens_per_second"] <= 0:
        bad(f"tokens_per_second {doc['tokens_per_second']} <= 0")
    joins, leaves = doc["joins"], doc["leaves"]
    if joins <= 0 or joins != leaves:
        bad(f"bad join/leave telemetry {joins}/{leaves}")
    if not 0 < doc["slot_occupancy"]["mean"] <= 1:
        bad(f"slot occupancy {doc['slot_occupancy']['mean']} out of range")
    if not 0 < doc["page_occupancy"]["mean"] <= 1:
        bad(f"page occupancy {doc['page_occupancy']['mean']} out of range")
    if doc["pages_in_use_at_drain"] != 0:
        bad(f"{doc['pages_in_use_at_drain']} pages leaked at drain")
    if doc["ws_buffer_allocs"] <= 0:
        bad("decode workspace never warmed (ws_buffer_allocs == 0)")
    capstop, trunc, requests = doc["capacity_stopped"], doc["truncated"], doc["requests"]
    if capstop < 1 or trunc < 1:
        bad(f"expected >=1 capacity-stopped and truncated, got {capstop}/{trunc}")
    # Outcome conservation, generalized for overload: every request ends in
    # exactly one of {admitted-and-retired, truncated, shed}; each
    # preemption re-counts its victim's readmission as a fresh join (or
    # resolves it slot-free into capacity_stopped), so unique admissions are
    # joins - preemptions at minimum.
    shed, preempt = doc["shed"], doc["preemptions"]
    if capstop + trunc + shed + joins - preempt < requests or capstop + trunc + shed > requests:
        bad(
            f"inconsistent outcome counters capstop {capstop} + trunc {trunc} "
            f"+ shed {shed} vs joins {joins}, preemptions {preempt}, requests {requests}"
        )
    if not 0 <= doc["goodput_under_slo"] <= 1:
        bad(f"goodput_under_slo {doc['goodput_under_slo']} outside [0, 1]")
    if preempt == 0 and doc["victim_recompute_tokens"] != 0:
        bad("victim recompute tokens without a preemption")
    lat = doc["latency_s"]
    missing = [q for q in ("p50", "p95", "p99") if q not in lat]
    if missing:
        bad(f"latency missing {missing}")
    elif not lat["p50"] <= lat["p95"] <= lat["p99"]:
        bad(f"unordered percentiles {lat}")
    qw = doc["queue_wait"]
    if qw["mean"] < 0:
        bad(f"negative mean queue wait {qw['mean']}")
    if qw["n"] != requests:
        bad(f"queue_wait n {qw['n']} != requests {requests}")
    # The four phase clocks are disjoint sub-intervals of the step loop, so
    # their sum must be positive (the engine did work) and must not exceed
    # the step wall-clock by more than float/bookkeeping slack.
    phase_sum = (
        doc["time_admit_s"] + doc["time_prefill_s"] + doc["time_decode_s"] + doc["time_retire_s"]
    )
    step_s = doc["time_step_s"]
    if phase_sum <= 0:
        bad("per-phase clocks never ran (phase sum == 0)")
    if phase_sum > step_s * 1.10:
        bad(f"phase sum {phase_sum:.6f}s exceeds step wall-clock {step_s:.6f}s")
    for fmt, secs in sorted(doc["kernel_time"].items()):
        if secs < 0:
            bad(f"negative kernel time {secs} for format {fmt}")
    return errs


def check_paged_pair(whole, paged):
    """Whole-cache vs paged arena: equal bytes, wider decode."""
    errs = []
    if whole["kv_arena_bytes"] != paged["kv_arena_bytes"]:
        errs.append(
            f"arena bytes differ ({whole['kv_arena_bytes']} vs {paged['kv_arena_bytes']}) "
            f"— the concurrency comparison must hold KV bytes equal"
        )
        return errs
    w_peak, p_peak = whole["decode_batch"]["max"], paged["decode_batch"]["max"]
    if p_peak <= w_peak:
        errs.append(
            f"paged arena must decode wider at equal bytes "
            f"(peak {p_peak} vs whole-cache {w_peak})"
        )
    return errs


def check_shared_pair(shared, noshare):
    """Shared-prefix vs opted-out run over the same workload and bytes."""
    errs = []
    if shared["kv_arena_bytes"] != noshare["kv_arena_bytes"]:
        errs.append(
            f"shared/unshared arena bytes differ "
            f"({shared['kv_arena_bytes']} vs {noshare['kv_arena_bytes']})"
        )
    if shared["prefill_tokens_saved"] <= 0:
        errs.append("shared-prefix run saved no prefill tokens")
    if shared["shared_pages"] <= 0:
        errs.append("shared-prefix run mapped no shared pages")
    if noshare["prefill_tokens_saved"] != 0 or noshare["shared_pages"] != 0:
        errs.append(
            f"opted-out run reused KV anyway "
            f"(saved {noshare['prefill_tokens_saved']}, pages {noshare['shared_pages']})"
        )
    ds, du = shared["completions_digest"], noshare["completions_digest"]
    if ds != du:
        errs.append(f"completions digests differ: shared {ds} vs unshared {du}")
    if ds == "0" * 16:
        errs.append("completions digest was never computed")
    return errs


def check_overload(overload, storm_on, storm_off):
    """Overload trio: preemption + shedding exercised, and bit-identity.

    ``overload`` ran with preemption and the shedder on under burst
    arrivals; ``storm_on``/``storm_off`` are the same storm with shedding
    off and preemption toggled, so their completions must be digest-equal
    at equal arena bytes (preemption is scheduling, never behaviour).
    """
    errs = []
    if overload["preemptions"] < 1:
        errs.append("overload run never preempted (the storm must force eviction)")
    elif overload["victim_recompute_tokens"] < 1:
        errs.append("overload run preempted but recomputed nothing")
    if overload["shed"] < 1:
        errs.append("overload run never shed (the backlog must blow the SLO)")
    if overload["goodput_under_slo"] <= 0:
        errs.append("overload run reports zero goodput under the SLO")
    fi = overload["first_token_latency_interactive"]
    fb = overload["first_token_latency_batch"]
    if fi["n"] < 1 or fb["n"] < 1:
        errs.append(
            f"overload run must serve both interactive and batch tiers "
            f"(got n={fi['n']}/{fb['n']})"
        )
    elif fi["p99"] > fb["p99"]:
        errs.append(
            f"priority inversion: interactive p99 first token {fi['p99']:.4f}s "
            f"exceeds batch p99 {fb['p99']:.4f}s"
        )
    if storm_on["kv_arena_bytes"] != storm_off["kv_arena_bytes"]:
        errs.append(
            f"storm arena bytes differ ({storm_on['kv_arena_bytes']} vs "
            f"{storm_off['kv_arena_bytes']}) — the A/B must hold KV bytes equal"
        )
    if storm_on["preemptions"] < 1:
        errs.append("storm_on run never preempted")
    if storm_off["preemptions"] != 0:
        errs.append(f"storm_off run preempted {storm_off['preemptions']} times with it off")
    if storm_on["shed"] != 0 or storm_off["shed"] != 0:
        errs.append("storm A/B must run with shedding off (shed decisions diverge)")
    ds, du = storm_on["completions_digest"], storm_off["completions_digest"]
    if ds != du:
        errs.append(f"completions digests differ: preemption-on {ds} vs off {du}")
    if ds == "0" * 16:
        errs.append("storm completions digest was never computed")
    return errs


def load_runs(serve_dir):
    """{filename: parsed doc} for every SERVE_*.json, sorted by name."""
    runs = {}
    for path in sorted(glob.glob(os.path.join(serve_dir, "SERVE_*.json"))):
        with open(path) as f:
            runs[os.path.basename(path)] = json.load(f)
    return runs


def pick(runs, tag):
    return next((d for name, d in runs.items() if tag in name), None)


def gate(
    runs,
    paged_tag,
    shared_tag,
    noshare_tag,
    require_shared,
    overload_tag="tiny_overload",
    storm_on_tag="tiny_storm_on",
    storm_off_tag="tiny_storm_off",
    require_overload=False,
):
    """All errors across per-run, pair, and overload-trio checks."""
    errs = []
    for name, doc in runs.items():
        errs.extend(check_run(name, doc))
    special = (paged_tag, shared_tag, noshare_tag, overload_tag, storm_on_tag, storm_off_tag)
    whole = next(
        (d for name, d in runs.items() if not any(t in name for t in special)), None
    )
    paged = pick(runs, paged_tag)
    if whole is None or paged is None:
        errs.append("missing whole-cache or paged run")
    else:
        errs.extend(check_paged_pair(whole, paged))
    shared, noshare = pick(runs, shared_tag), pick(runs, noshare_tag)
    if shared is not None and noshare is not None:
        errs.extend(check_shared_pair(shared, noshare))
    elif require_shared:
        errs.append(f"missing {shared_tag} or {noshare_tag} run")
    trio = [pick(runs, t) for t in (overload_tag, storm_on_tag, storm_off_tag)]
    if all(d is not None for d in trio):
        errs.extend(check_overload(*trio))
    elif require_overload:
        errs.append(f"missing {overload_tag}, {storm_on_tag}, or {storm_off_tag} run")
    return errs


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--serve-dir", default="serve-out")
    ap.add_argument("--paged-tag", default="tiny_paged")
    ap.add_argument("--shared-tag", default="tiny_shared")
    ap.add_argument("--noshare-tag", default="tiny_noshare")
    ap.add_argument(
        "--require-shared",
        action="store_true",
        help="fail when the shared/unshared A/B pair is absent (CI sets this)",
    )
    ap.add_argument("--overload-tag", default="tiny_overload")
    ap.add_argument("--storm-on-tag", default="tiny_storm_on")
    ap.add_argument("--storm-off-tag", default="tiny_storm_off")
    ap.add_argument(
        "--require-overload",
        action="store_true",
        help="fail when the overload/storm trio is absent (CI sets this)",
    )
    args = ap.parse_args(argv)

    runs = load_runs(args.serve_dir)
    if not runs:
        print(f"serve gate: no SERVE_*.json in {args.serve_dir}", file=sys.stderr)
        return 1
    errs = gate(
        runs,
        args.paged_tag,
        args.shared_tag,
        args.noshare_tag,
        args.require_shared,
        args.overload_tag,
        args.storm_on_tag,
        args.storm_off_tag,
        args.require_overload,
    )
    for name, doc in runs.items():
        print(
            f"run {name}: {doc.get('tokens_per_second', 0):.1f} tok/s, "
            f"joins {doc.get('joins')}, truncated {doc.get('truncated')}, "
            f"capacity-stopped {doc.get('capacity_stopped')}, "
            f"prefill saved {doc.get('prefill_tokens_saved')}, "
            f"shared pages {doc.get('shared_pages')}, "
            f"cow forks {doc.get('cow_forks')}, "
            f"preemptions {doc.get('preemptions')}, shed {doc.get('shed')}"
        )
    print(f"serve gate: {len(runs)} runs checked")
    if errs:
        print("serve gate failed:\n" + "\n".join(errs), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
