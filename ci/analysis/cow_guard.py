"""cow-guard: KV row write paths stay behind the copy-on-write guard.

``KvCache::k_row_mut`` / ``v_row_mut`` panic on shared
(``Arc``-refcounted prefix) pages — that panic *is* the CoW guard that
keeps shared-prefix reuse an optimization rather than a behaviour. The
attention write paths in ``model/lm.rs`` are the only audited callers:
the engine routes every write through ``decode_step``/
``decode_step_batch``, which fork a shared page (``KvPool::fork_page``)
before any write can land in it.

A new direct call site elsewhere would bypass that fork discipline and
turn the guard panic into a production crash (or, worse, motivate
someone to remove the panic). This rule restricts call sites to
``model/lm.rs`` plus an explicit allowlist of fork-guarded engine sites
(currently empty — extend ``ALLOWED_FILES`` in a PR that demonstrates
the fork happens first).
"""

from __future__ import annotations

import re

from tidy_core import Finding

RULE_ID = "cow-guard"
DESCRIPTION = "k_row_mut/v_row_mut calls only in model/lm.rs (+ fork-guarded allowlist)"

# model/lm.rs owns the write paths; add fork-guarded engine sites here
# explicitly, with a review that shows KvPool::fork_page precedes the write.
ALLOWED_FILES = ("rust/src/model/lm.rs",)

CALL_RE = re.compile(r"\.\s*(k_row_mut|v_row_mut)\s*\(")


def check(scan):
    findings = []
    for src in scan.rust_files():
        if src.path in ALLOWED_FILES:
            continue
        for m in CALL_RE.finditer(src.code):
            findings.append(
                Finding(
                    RULE_ID,
                    src.path,
                    src.line_of(m.start()),
                    f"`{m.group(1)}` called outside model/lm.rs — KV row "
                    "writes must stay behind the CoW fork discipline "
                    "(panics on shared prefix pages)",
                )
            )
    return findings
