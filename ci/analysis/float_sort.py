"""float-sort: comparators must use ``total_cmp``, never
``partial_cmp(..).unwrap()``.

``partial_cmp`` returns ``None`` for NaN, so a
``sort_by(|a, b| a.partial_cmp(b).unwrap())`` comparator panics the
moment a NaN reaches it — mid-run, with a `called unwrap on None`
message that names no culprit. PR 4 fixed exactly this class in
``Summary::of`` after a NaN latency observation panicked the serve
telemetry; a grep then found four more live instances on the
calibration/compression paths where 0/0 saliency scores are one dead
calibration column away. ``f32::total_cmp``/``f64::total_cmp`` is the
total order the standard library provides for exactly this purpose.

The rule flags ``partial_cmp`` immediately unwrapped inside the
comparator argument of ``sort_by`` / ``sort_unstable_by`` / ``max_by`` /
``min_by``. ``unwrap_or(...)`` fallbacks are tolerated (NaN-safe, if
order-fuzzy); use total_cmp for new code.
"""

from __future__ import annotations

import re

from tidy_core import Finding

RULE_ID = "float-sort"
DESCRIPTION = "ban partial_cmp(..).unwrap() comparators; require total_cmp"

SORT_RE = re.compile(r"\b(sort_by|sort_unstable_by|max_by|min_by)\s*\(")
PARTIAL_UNWRAP_RE = re.compile(r"partial_cmp\b[^;]*?\.\s*unwrap\s*\(\s*\)")


def _balanced_span(code, open_paren):
    """End offset of the parenthesized span starting at ``open_paren``."""
    depth = 0
    for i in range(open_paren, len(code)):
        c = code[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(code)


def check(scan):
    findings = []
    for src in scan.rust_files():
        code = src.code
        for m in SORT_RE.finditer(code):
            open_paren = m.end() - 1
            arg = code[open_paren:_balanced_span(code, open_paren)]
            pu = PARTIAL_UNWRAP_RE.search(arg)
            if pu:
                findings.append(
                    Finding(
                        RULE_ID,
                        src.path,
                        src.line_of(open_paren + pu.start()),
                        f"`{m.group(1)}` comparator unwraps `partial_cmp` — "
                        "panics on NaN; use `total_cmp` (preserving the "
                        "sort direction)",
                    )
                )
    return findings
