"""dim-source: forward-path code reads layer dims from the layer, not cfg.

With rotate-and-slice in the pipeline, a block's FFN width is no longer
``cfg.d_ff`` — a sliced pair runs in its kept width, and only the layer
itself (``LinearOp::in_dim``/``out_dim``) knows it. The forward-path
refactor sourced every activation-buffer size and loop bound from the
layer ops; a new ``cfg.d_model``/``cfg.d_ff`` read inside a forward-path
function would silently re-assume uniform shapes and panic (or worse,
read garbage) the first time a sliced checkpoint is served.

This rule walks the bodies of the forward-path functions in
``rust/src/model/`` and flags any ``cfg.d_model`` / ``cfg.d_ff`` token.
Construction-time code (``init``, ``KvPage::new``, checkpoint IO, tests)
is out of scope: allocating by config there is correct — shapes are
being *created*, not *assumed*.
"""

from __future__ import annotations

import re

from tidy_core import Finding

RULE_ID = "dim-source"
DESCRIPTION = "forward-path fns in model/ read dims from LinearOp, not cfg.d_model/d_ff"

# Longest-first so the alternation never stops at a prefix of a longer name.
FN_RE = re.compile(
    r"\bfn\s+(decode_step_batch_ws|decode_step_batch|decode_step"
    r"|block_forward|forward_ws|forward_vec|forward)\s*[(<]"
)
DIM_RE = re.compile(r"\bcfg\s*\.\s*d_(model|ff)\b")
MODEL_PREFIX = "rust/src/model/"


def _body_span(code, start):
    """(open, close) offsets of the brace-matched body after ``start``."""
    open_i = code.find("{", start)
    if open_i == -1:
        return None
    depth = 0
    for j in range(open_i, len(code)):
        c = code[j]
        if c == "{":
            depth += 1
        elif c == "}":
            depth -= 1
            if depth == 0:
                return (open_i, j + 1)
    return (open_i, len(code))


def check(scan):
    findings = []
    for src in scan.rust_files():
        if not src.path.startswith(MODEL_PREFIX):
            continue
        for fm in FN_RE.finditer(src.code):
            span = _body_span(src.code, fm.end())
            if span is None:
                continue
            body = src.code[span[0] : span[1]]
            for dm in DIM_RE.finditer(body):
                off = span[0] + dm.start()
                findings.append(
                    Finding(
                        RULE_ID,
                        src.path,
                        src.line_of(off),
                        f"`cfg.d_{dm.group(1)}` read inside `{fm.group(1)}` — "
                        "forward-path dims must come from the layer "
                        "(`LinearOp::in_dim`/`out_dim`); sliced layers run "
                        "in their kept width, not the config width",
                    )
                )
    return findings
