"""Shared infrastructure for the oats-tidy static analysis rules.

Everything here is dependency-free standard library, mirroring the
``ci/gates/`` convention: rule modules import this, ``oats_tidy.py``
drives them, and ``python/tests/test_oats_tidy.py`` exercises both
against synthetic fixture trees.

The load-bearing piece is :func:`lex_rust`, a line-preserving lexer that
blanks out comments and string/char literals from Rust source while
collecting the comment text per line. Rules that look for *code* tokens
(``unsafe``, ``mul_add``, ``partial_cmp``...) scan the stripped text so a
doc comment *mentioning* a banned construct never trips a lint; rules
that look for *comments* (``// SAFETY:``, ``// tidy-allow(...)``) read
the collected comment map.
"""

from __future__ import annotations

import os
import re


class Finding:
    """One rule violation at a file:line, plus whether it was suppressed."""

    def __init__(self, rule, path, line, message):
        self.rule = rule
        self.path = path  # repo-relative, forward slashes
        self.line = line  # 1-based
        self.message = message
        self.suppressed = False
        self.suppress_reason = ""

    def __repr__(self):
        tag = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}{tag}"


class SourceFile:
    """One lexed source file.

    Attributes:
        path: repo-relative path with forward slashes.
        text: raw contents.
        code: contents with comments and string/char literal *bodies*
            blanked to spaces (newlines and quote delimiters kept, so
            offsets and line numbers are unchanged).
        comment_lines: {1-based line: concatenated comment text on it}.
    """

    def __init__(self, path, text):
        self.path = path
        self.text = text
        self.code, self.comment_lines = lex_rust(text)
        self._code_with_strings = None
        self._line_starts = None

    @property
    def code_with_strings(self):
        """Like ``code`` but with string literal contents preserved —
        for rules that read emitted keys out of string literals."""
        if self._code_with_strings is None:
            self._code_with_strings, _ = lex_rust(self.text, keep_strings=True)
        return self._code_with_strings

    def line_of(self, offset):
        """1-based line number of a character offset into the text."""
        if self._line_starts is None:
            starts = [0]
            for i, ch in enumerate(self.text):
                if ch == "\n":
                    starts.append(i + 1)
            self._line_starts = starts
        import bisect

        return bisect.bisect_right(self._line_starts, offset)

    def code_lines(self):
        """Stripped code split into lines (index 0 = line 1)."""
        return self.code.split("\n")


def lex_rust(text, keep_strings=False):
    """Blank comments and string/char literals out of Rust source.

    Returns ``(code, comment_lines)`` where ``code`` has the same length
    and line structure as ``text`` but with comment text and string/char
    contents replaced by spaces, and ``comment_lines`` maps 1-based line
    numbers to the comment text that appears on them (line comments,
    block comments — including every line a multi-line block spans).

    Handles line comments, nested block comments, plain/byte strings
    with escapes, raw strings (``r"…"``, ``r#"…"#``, ``br##"…"##``), and
    char literals vs lifetimes.
    """
    n = len(text)
    out = list(text)
    comments = {}
    line = 1
    i = 0

    def blank(j):
        if out[j] != "\n":
            out[j] = " "

    def blank_str(j):
        if not keep_strings and out[j] != "\n":
            out[j] = " "

    def note_comment(ln, s):
        comments[ln] = comments.get(ln, "") + s

    raw_open = re.compile(r'(?:b?r)(#*)"')

    while i < n:
        ch = text[i]
        nxt = text[i + 1] if i + 1 < n else ""
        if ch == "\n":
            line += 1
            i += 1
            continue
        if ch == "/" and nxt == "/":
            j = i
            while j < n and text[j] != "\n":
                blank(j)
                j += 1
            note_comment(line, text[i:j])
            i = j
            continue
        if ch == "/" and nxt == "*":
            depth = 1
            j = i + 2
            buf = "/*"
            blank(i)
            blank(i + 1)
            cur = line
            while j < n and depth > 0:
                if text[j] == "/" and j + 1 < n and text[j + 1] == "*":
                    depth += 1
                    buf += "/*"
                    blank(j)
                    blank(j + 1)
                    j += 2
                elif text[j] == "*" and j + 1 < n and text[j + 1] == "/":
                    depth -= 1
                    buf += "*/"
                    blank(j)
                    blank(j + 1)
                    j += 2
                elif text[j] == "\n":
                    note_comment(cur, buf)
                    buf = ""
                    cur += 1
                    j += 1
                else:
                    buf += text[j]
                    blank(j)
                    j += 1
            if buf:
                note_comment(cur, buf)
            line = cur
            i = j
            continue
        m = raw_open.match(text, i)
        if m and (i == 0 or not (text[i - 1].isalnum() or text[i - 1] == "_")):
            hashes = m.group(1)
            j = m.end()
            close = '"' + hashes
            end = text.find(close, j)
            if end == -1:
                end = n
            for k in range(j, end):
                blank_str(k)
            line += text.count("\n", j, end)
            i = end + len(close)
            continue
        if ch == '"' or (ch == "b" and nxt == '"'):
            j = i + (2 if ch == "b" else 1)
            while j < n:
                if text[j] == "\\":
                    blank_str(j)
                    if j + 1 < n:
                        blank_str(j + 1)
                    j += 2
                    continue
                if text[j] == '"':
                    break
                if text[j] == "\n":
                    line += 1
                    j += 1
                    continue
                blank_str(j)
                j += 1
            i = j + 1
            continue
        if ch == "'":
            # char literal iff 'x' or '\...' closes with a quote; else a
            # lifetime / label tick.
            if nxt == "\\":
                j = i + 2
                while j < n and text[j] != "'":
                    blank_str(j)
                    j += 1
                blank_str(i + 1)
                i = j + 1
                continue
            if i + 2 < n and text[i + 2] == "'" and nxt != "'":
                blank_str(i + 1)
                i = i + 3
                continue
            i += 1
            continue
        i += 1
    return "".join(out), comments


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------

SUPPRESS_RE = re.compile(r"tidy-allow\(([a-z0-9_-]+)\)\s*:?\s*(.*)")


def collect_suppressions(src):
    """``{rule: {line: reason}}`` for every tidy-allow comment in a file.

    A suppression on line N covers findings of that rule on line N and on
    line N+1 (the comment-above-the-offending-line style).
    """
    sups = {}
    for ln, comment in src.comment_lines.items():
        for m in SUPPRESS_RE.finditer(comment):
            rule, reason = m.group(1), m.group(2).strip()
            sups.setdefault(rule, {})[ln] = reason
    return sups


def apply_suppressions(findings, scan):
    """Mark findings covered by a tidy-allow comment as suppressed.

    Returns the list of (path, line, rule, reason) suppressions that were
    actually used, so the CLI can report them (suppressions are tracked,
    never silent).
    """
    used = []
    by_path = {}
    for f in findings:
        by_path.setdefault(f.path, []).append(f)
    for path, fs in by_path.items():
        src = scan.file(path)
        if src is None:
            continue
        sups = collect_suppressions(src)
        for f in fs:
            lines = sups.get(f.rule, {})
            for ln in (f.line, f.line - 1):
                if ln in lines:
                    f.suppressed = True
                    f.suppress_reason = lines[ln]
                    used.append((path, ln, f.rule, lines[ln]))
                    break
    return used


# ---------------------------------------------------------------------------
# Repo scan
# ---------------------------------------------------------------------------

# Directories holding first-party Rust code. rust/vendor is excluded: the
# shims there mirror external crates and are not held to in-repo contracts.
RUST_WALK_ROOTS = ("rust/src", "rust/tests", "rust/benches", "examples")


class RepoScan:
    """Lazy view of the repository's first-party Rust tree."""

    def __init__(self, root):
        self.root = os.path.abspath(root)
        self._files = {}
        self._rust_paths = None

    def rust_paths(self):
        if self._rust_paths is None:
            paths = []
            for rel_root in RUST_WALK_ROOTS:
                top = os.path.join(self.root, rel_root)
                for dirpath, dirnames, filenames in os.walk(top):
                    dirnames.sort()
                    for name in sorted(filenames):
                        if name.endswith(".rs"):
                            full = os.path.join(dirpath, name)
                            paths.append(
                                os.path.relpath(full, self.root).replace(os.sep, "/")
                            )
            self._rust_paths = paths
        return self._rust_paths

    def file(self, rel_path):
        """SourceFile for a repo-relative path, or None if unreadable."""
        if rel_path not in self._files:
            full = os.path.join(self.root, rel_path.replace("/", os.sep))
            try:
                with open(full, encoding="utf-8") as f:
                    text = f.read()
            except OSError:
                self._files[rel_path] = None
            else:
                self._files[rel_path] = SourceFile(rel_path, text)
        return self._files[rel_path]

    def rust_files(self):
        for p in self.rust_paths():
            src = self.file(p)
            if src is not None:
                yield src
