"""thread-probe: ``available_parallelism`` lives in util/threadpool.rs only.

PR 5 found the per-call ``std::thread::available_parallelism()`` syscall
in the serve decode profile — every batched product in every engine step
paid it — and centralized the probe behind a process-wide ``OnceLock``
(``util::threadpool::{detected_parallelism, available_threads}``). This
rule keeps it that way: any new call site must go through the cached
accessor, not the raw syscall.
"""

from __future__ import annotations

import re

from tidy_core import Finding

RULE_ID = "thread-probe"
DESCRIPTION = "available_parallelism only in util/threadpool.rs (OnceLock cache)"

ALLOWED_FILES = ("rust/src/util/threadpool.rs",)
PROBE_RE = re.compile(r"\bavailable_parallelism\b")


def check(scan):
    findings = []
    for src in scan.rust_files():
        if src.path in ALLOWED_FILES:
            continue
        for m in PROBE_RE.finditer(src.code):
            findings.append(
                Finding(
                    RULE_ID,
                    src.path,
                    src.line_of(m.start()),
                    "`available_parallelism` outside util/threadpool.rs — "
                    "use `util::threadpool::available_threads()` (cached, "
                    "one syscall per process)",
                )
            )
    return findings
