"""unsafe-hygiene: every ``unsafe`` site must carry a ``// SAFETY:`` comment.

rustc-tidy style: an ``unsafe`` block, fn, impl, or trait is only
acceptable when a comment containing ``SAFETY:`` sits on the same line or
directly above it (blank lines and attribute lines like
``#[target_feature(...)]`` may sit between the comment and the keyword;
any other code line breaks the association).

The comment is the contract: it states *why* the invariants hold, which
is exactly the part the compiler cannot check and reviewers forget to
demand. The repo keeps its entire unsafe surface in three files (the
microkernel scatter, the GEMM stripe split, the SendPtr wrapper) — this
rule keeps it documented as it grows.
"""

from __future__ import annotations

import re

from tidy_core import Finding

RULE_ID = "unsafe-hygiene"
DESCRIPTION = "unsafe blocks/fns/impls need an adjacent // SAFETY: comment"

UNSAFE_RE = re.compile(r"\bunsafe\b")
ATTR_RE = re.compile(r"^\s*#!?\[")
# How far above the unsafe keyword the SAFETY comment may start, counting
# only comment/blank/attribute lines in between.
MAX_WALK = 12


def _has_adjacent_safety(src, line):
    """True when a SAFETY: comment is on `line` or directly above it."""
    if "SAFETY:" in src.comment_lines.get(line, ""):
        return True
    code = src.code_lines()
    for ln in range(line - 1, max(0, line - MAX_WALK), -1):
        comment = src.comment_lines.get(ln, "")
        if "SAFETY:" in comment:
            return True
        code_ln = code[ln - 1] if ln - 1 < len(code) else ""
        stripped = code_ln.strip()
        if not stripped or ATTR_RE.match(code_ln) or comment:
            continue  # blank, attribute, or pure-comment line: keep walking
        return False  # a real code line severs the association
    return False


def check(scan):
    findings = []
    for src in scan.rust_files():
        seen_lines = set()
        for m in UNSAFE_RE.finditer(src.code):
            line = src.line_of(m.start())
            if line in seen_lines:
                continue  # one finding per line even with two unsafe tokens
            seen_lines.add(line)
            if not _has_adjacent_safety(src, line):
                findings.append(
                    Finding(
                        RULE_ID,
                        src.path,
                        line,
                        "`unsafe` without an adjacent `// SAFETY:` comment "
                        "stating why the invariants hold",
                    )
                )
    return findings
