#!/usr/bin/env python3
"""oats-tidy: in-repo contract-enforcement static analysis.

The codebase's load-bearing guarantees — the bit-identity numerics
contract every serve-engine property test rests on, the CoW shared-page
guard, the cached thread probe, the hand-mirrored telemetry schema —
were enforced only by reviewer discipline. This CLI makes them
mechanical: a dependency-free walk of the Rust tree plus the committed
schema lock, failing CI on any violation with ``file:line`` findings.

Usage::

    python3 ci/analysis/oats_tidy.py --all              # every rule (CI)
    python3 ci/analysis/oats_tidy.py float-sort cow-guard
    python3 ci/analysis/oats_tidy.py --list-rules
    python3 ci/analysis/oats_tidy.py --list-suppressions
    python3 ci/analysis/oats_tidy.py schema-lock --update-lock

Suppression: a finding is waived by a comment on the same line or the
line above it::

    // tidy-allow(float-sort): scores are clamped finite two lines up
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());

Suppressions are tracked — ``--list-suppressions`` prints every one in
the tree, and the summary line counts them — so waivers stay greppable
and reviewable instead of invisible.

Exit status: 0 when no unsuppressed findings, 1 otherwise (2 on usage
errors).
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import cow_guard  # noqa: E402
import dim_source  # noqa: E402
import float_sort  # noqa: E402
import numerics_contract  # noqa: E402
import schema_lock  # noqa: E402
import thread_probe  # noqa: E402
import trace_hygiene  # noqa: E402
import unsafe_hygiene  # noqa: E402
from tidy_core import RepoScan, apply_suppressions, collect_suppressions  # noqa: E402

RULE_MODULES = [
    unsafe_hygiene,
    numerics_contract,
    float_sort,
    thread_probe,
    cow_guard,
    dim_source,
    trace_hygiene,
    schema_lock,
]
RULES = {m.RULE_ID: m for m in RULE_MODULES}

DEFAULT_ROOT = os.path.normpath(
    os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..")
)


def run_rules(scan, rule_ids):
    """All findings for the requested rules, suppressions applied.

    Returns ``(findings, used_suppressions)``.
    """
    findings = []
    for rid in rule_ids:
        findings.extend(RULES[rid].check(scan))
    used = apply_suppressions(findings, scan)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings, used


def list_suppressions(scan):
    """Every tidy-allow comment in the tree as (path, line, rule, reason)."""
    out = []
    for src in scan.rust_files():
        for rule, lines in sorted(collect_suppressions(src).items()):
            for ln, reason in sorted(lines.items()):
                out.append((src.path, ln, rule, reason))
    return sorted(out)


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="oats_tidy.py", description=__doc__.splitlines()[0]
    )
    ap.add_argument("rules", nargs="*", help="rule ids to run (see --list-rules)")
    ap.add_argument("--all", action="store_true", help="run every rule")
    ap.add_argument("--root", default=DEFAULT_ROOT, help="repository root")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument(
        "--list-suppressions",
        action="store_true",
        help="print every tidy-allow comment in the tree and exit",
    )
    ap.add_argument(
        "--update-lock",
        action="store_true",
        help="regenerate ci/analysis/schema_lock.json from live extraction "
        "(review the diff before committing; CI never does this)",
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for m in RULE_MODULES:
            print(f"{m.RULE_ID:18} {m.DESCRIPTION}")
        return 0

    scan = RepoScan(args.root)

    if args.list_suppressions:
        sups = list_suppressions(scan)
        for path, ln, rule, reason in sups:
            print(f"{path}:{ln}: tidy-allow({rule}): {reason or '<no reason>'}")
        print(f"oats-tidy: {len(sups)} suppression(s) in tree")
        return 0

    if args.update_lock:
        path = schema_lock.write_lock(scan)
        print(f"oats-tidy: schema lock regenerated -> {path}")
        if not (args.all or args.rules):
            return 0

    if args.all:
        rule_ids = list(RULES)
    else:
        rule_ids = args.rules
        unknown = [r for r in rule_ids if r not in RULES]
        if unknown:
            ap.error(f"unknown rule(s): {', '.join(unknown)} (see --list-rules)")
        if not rule_ids:
            ap.error("no rules requested (use --all or name rules)")

    findings, used = run_rules(scan, rule_ids)
    live = [f for f in findings if not f.suppressed]
    for f in live:
        print(f"{f.path}:{f.line}: [{f.rule}] {f.message}")
    for path, ln, rule, reason in used:
        print(f"note: suppressed at {path}:{ln}: tidy-allow({rule}): {reason}")
    n_files = len(list(scan.rust_paths()))
    print(
        f"oats-tidy: {len(live)} finding(s), {len(used)} suppressed, "
        f"{len(rule_ids)} rule(s) over {n_files} files"
    )
    return 1 if live else 0


if __name__ == "__main__":
    sys.exit(main())
