"""numerics-contract: no FMA contraction or fast-math in the kernel paths.

The serve engine's property tests rest on a documented contract
(``sparse/microkernel.rs``): results are **bit-identical** across batch
widths, lane/tail splits, and SIMD-vs-generic dispatch, because every
output element folds its nonzeros in index order with plain mul-then-add
f32 arithmetic. A single ``mul_add`` (one rounding instead of two), an
explicit ``_mm*_fmadd``-family intrinsic, or a fast-math intrinsic
anywhere in the kernel tree silently breaks that equivalence — the tests
would only catch it on a host whose dispatch actually diverges.

This rule bans those constructs inside the contract paths:
``rust/src/sparse/``, ``rust/src/tensor.rs``, and ``rust/src/model/``.
Code elsewhere (experiments, eval, vit) may use them freely.
"""

from __future__ import annotations

import re

from tidy_core import Finding

RULE_ID = "numerics-contract"
DESCRIPTION = "no mul_add / FMA intrinsics / fast-math in the bit-identity kernel paths"

# Paths covered by the bit-identity contract. A trailing slash means the
# whole subtree.
CONTRACT_PATHS = ("rust/src/sparse/", "rust/src/tensor.rs", "rust/src/model/")

BANNED = [
    (re.compile(r"\bmul_add\b"), "`mul_add` contracts mul+add into one rounding"),
    (
        re.compile(r"\b_mm\d*_maskz?_?fn?m(?:add|sub)\w*\b|\b_mm\d*_fn?m(?:add|sub)\w*\b"),
        "FMA-family intrinsic",
    ),
    (
        re.compile(r"\bf(?:add|sub|mul|div|rem)_(?:fast|algebraic)\b"),
        "fast-math intrinsic relaxes IEEE semantics",
    ),
]


def in_contract_path(path):
    return any(
        path == p or (p.endswith("/") and path.startswith(p)) for p in CONTRACT_PATHS
    )


def check(scan):
    findings = []
    for src in scan.rust_files():
        if not in_contract_path(src.path):
            continue
        for pattern, why in BANNED:
            for m in pattern.finditer(src.code):
                findings.append(
                    Finding(
                        RULE_ID,
                        src.path,
                        src.line_of(m.start()),
                        f"{why}: `{m.group(0)}` would break the "
                        "bit-identity-across-lane-splits contract "
                        "(see sparse/microkernel.rs module docs)",
                    )
                )
    return findings
