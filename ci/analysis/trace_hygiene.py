"""trace-hygiene: span names must be 'static snake_case literals from the
committed registry.

The trace recorder stores event names as ``&'static str`` and never
copies them, so a name is an identity, not a message: the Perfetto
timeline groups by it, ``trace_gate.py`` keys its lifecycle chains on
it, and the SERVE json folds ``kernel_*`` span durations by it. A name
built at runtime (or invented ad hoc at one call site) silently forks
that taxonomy — the gate stops seeing the events and nobody notices,
because a trace with a misspelled span still loads fine.

The rule therefore requires every ``trace::span`` / ``span_args`` /
``instant`` / ``instant_args`` / ``counter`` / ``timed`` call site to
pass a string literal, snake_case (``[a-z][a-z0-9_]*``), that appears in
``ci/analysis/trace_registry.json``. Adding a span means adding its name
to the registry in the same PR — the registry diff is the review
surface for taxonomy growth.

``rust/src/util/trace.rs`` itself is exempt: the recorder's unit tests
exercise the API with throwaway probe names that deliberately stay out
of the production taxonomy.
"""

from __future__ import annotations

import json
import os
import re

from tidy_core import Finding

RULE_ID = "trace-hygiene"
DESCRIPTION = "trace span names must be snake_case literals from trace_registry.json"

REGISTRY_REL = "ci/analysis/trace_registry.json"

CALL_RE = re.compile(r"\btrace::(span_args|span|instant_args|instant|counter|timed)\s*\(")
# First argument: a string literal, possibly on the following line
# (rustfmt breaks wide call sites one-arg-per-line).
LITERAL_RE = re.compile(r'\s*"([^"]*)"')
SNAKE_RE = re.compile(r"[a-z][a-z0-9_]*")

# The recorder's own unit tests probe the API with unit_probe_* names.
EXEMPT = ("rust/src/util/trace.rs",)


def load_registry(scan):
    """(sorted name list, error message or None) from the committed registry."""
    path = os.path.join(scan.root, REGISTRY_REL.replace("/", os.sep))
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
        names = doc["names"]
        if not isinstance(names, list) or not all(isinstance(n, str) for n in names):
            raise ValueError("names must be a list of strings")
    except (OSError, ValueError, KeyError) as e:
        return [], f"{REGISTRY_REL} missing or unparseable ({e})"
    return sorted(names), None


def check(scan):
    findings = []
    registry, reg_err = load_registry(scan)
    if reg_err:
        findings.append(Finding(RULE_ID, REGISTRY_REL, 1, reg_err))
    names = set(registry)
    for src in scan.rust_files():
        if src.path in EXEMPT:
            continue
        code = src.code_with_strings
        for m in CALL_RE.finditer(code):
            line = src.line_of(m.start())
            lit = LITERAL_RE.match(code, m.end())
            if lit is None:
                findings.append(
                    Finding(
                        RULE_ID,
                        src.path,
                        line,
                        f"`trace::{m.group(1)}` name is not a string literal — "
                        "the recorder needs a 'static registry name, not a "
                        "runtime-built string",
                    )
                )
                continue
            name = lit.group(1)
            if SNAKE_RE.fullmatch(name) is None:
                findings.append(
                    Finding(
                        RULE_ID,
                        src.path,
                        line,
                        f'trace name "{name}" is not snake_case '
                        "([a-z][a-z0-9_]*)",
                    )
                )
            elif not reg_err and name not in names:
                findings.append(
                    Finding(
                        RULE_ID,
                        src.path,
                        line,
                        f'trace name "{name}" is not in {REGISTRY_REL} — '
                        "register it (sorted) in the same PR",
                    )
                )
    return findings
