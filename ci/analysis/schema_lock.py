"""schema-lock: the telemetry JSON schema is a two-sided committed contract.

The ``SERVE_*.json`` / ``BENCH_*.json`` keys are hand-mirrored in three
places: the Rust emitters (``ServeStats::to_json`` in
``coordinator/serve.rs``, ``Bench``/``BenchResult::to_json`` in
``bench.rs``, the shared ``Summary::to_json`` block in
``util/stats.rs``), the CI gate readers (``ci/gates/serve_gate.py``,
``ci/gates/bench_gate.py``), and — implicitly — every archived CI
artifact. Nothing machine-checks the mirror today: rename a key on one
side and the gate either crashes (KeyError mid-CI) or, for ``.get``
reads, silently stops checking anything.

This rule locks the schema in ``ci/analysis/schema_lock.json``:

* **Emitters**: every string key passed to ``.set("…", …)`` in each
  locked emitter file is extracted and diffed against the lock — a key
  added without a lock update fails, and a key deleted from the emitter
  while still locked fails. Drift is loud in *both* directions.
* **Gate reads**: every string key each gate file reads (``doc["k"]`` /
  ``doc.get("k")``) is diffed against the lock the same way, and —
  ignore-listed gate-internal keys aside — must be emitted by some
  locked emitter. A gate reading a key nothing emits fails the build.

Lock update procedure (for *intentional* schema changes)::

    python3 ci/analysis/oats_tidy.py schema-lock --update-lock
    git diff ci/analysis/schema_lock.json   # review: is every change intended?
    git add ci/analysis/schema_lock.json    # commit with the emitter change

CI never writes the lock — the committed file is the contract, so a PR
that drifts the schema cannot also re-lock it unreviewed.
"""

from __future__ import annotations

import json
import os
import re

from tidy_core import Finding

RULE_ID = "schema-lock"
DESCRIPTION = "telemetry keys emitted by Rust == committed lock == keys gates read"

LOCK_PATH = "ci/analysis/schema_lock.json"

# Rust `.set("key", …)` — the one JSON-building idiom the codebase uses.
RUST_SET_RE = re.compile(r'\.set\(\s*"([A-Za-z0-9_]+)"')
# Python reads: subscript with a literal key (excluding stores: `]` followed
# by a single `=`), and .get("key", …).
PY_SUB_RE = re.compile(r"""\[\s*(['"])([A-Za-z0-9_]+)\1\s*\](?!\s*=(?!=))""")
PY_GET_RE = re.compile(r"""\.get\(\s*(['"])([A-Za-z0-9_]+)\1""")


def load_lock(scan):
    full = os.path.join(scan.root, LOCK_PATH)
    try:
        with open(full, encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def extract_emitted_keys(src):
    """{key: first line} for every ``.set("key", …)`` in a Rust emitter."""
    keys = {}
    for m in RUST_SET_RE.finditer(src.code_with_strings):
        keys.setdefault(m.group(1), src.line_of(m.start()))
    return keys


def extract_gate_reads(text):
    """{key: first line} for every literal key a gate script reads."""
    keys = {}
    line_starts = [0]
    for i, ch in enumerate(text):
        if ch == "\n":
            line_starts.append(i + 1)
    import bisect

    def line_of(off):
        return bisect.bisect_right(line_starts, off)

    for regex in (PY_SUB_RE, PY_GET_RE):
        for m in regex.finditer(text):
            keys.setdefault(m.group(2), line_of(m.start()))
    return keys


def _read_text(scan, rel_path):
    try:
        with open(os.path.join(scan.root, rel_path), encoding="utf-8") as f:
            return f.read()
    except OSError:
        return None


UPDATE_HINT = (
    "intentional change? run `python3 ci/analysis/oats_tidy.py schema-lock "
    "--update-lock`, review the diff, and commit the lock"
)


def check(scan):
    findings = []
    lock = load_lock(scan)
    if lock is None:
        findings.append(
            Finding(RULE_ID, LOCK_PATH, 1, "schema lock missing or unparseable")
        )
        return findings

    all_emitted = set()
    for emitter_path, locked_keys in sorted(lock.get("emitters", {}).items()):
        src = scan.file(emitter_path)
        if src is None:
            findings.append(
                Finding(
                    RULE_ID, LOCK_PATH, 1, f"locked emitter {emitter_path} not found"
                )
            )
            continue
        live = extract_emitted_keys(src)
        all_emitted.update(live)
        locked = set(locked_keys)
        for key in sorted(set(live) - locked):
            findings.append(
                Finding(
                    RULE_ID,
                    emitter_path,
                    live[key],
                    f"emitted key \"{key}\" is not in the schema lock — "
                    f"{UPDATE_HINT}",
                )
            )
        for key in sorted(locked - set(live)):
            findings.append(
                Finding(
                    RULE_ID,
                    emitter_path,
                    1,
                    f"locked key \"{key}\" is no longer emitted here — "
                    "archived consumers and the gates still expect it; "
                    f"{UPDATE_HINT}",
                )
            )

    for gate_path, entry in sorted(lock.get("gates", {}).items()):
        text = _read_text(scan, gate_path)
        if text is None:
            findings.append(
                Finding(RULE_ID, LOCK_PATH, 1, f"locked gate {gate_path} not found")
            )
            continue
        ignore = set(entry.get("ignore", []))
        live = {
            k: ln for k, ln in extract_gate_reads(text).items() if k not in ignore
        }
        locked = set(entry.get("reads", []))
        for key in sorted(set(live) - locked):
            findings.append(
                Finding(
                    RULE_ID,
                    gate_path,
                    live[key],
                    f"gate reads key \"{key}\" not recorded in the schema "
                    f"lock — {UPDATE_HINT}",
                )
            )
        for key in sorted(locked - set(live)):
            findings.append(
                Finding(
                    RULE_ID,
                    gate_path,
                    1,
                    f"locked gate read \"{key}\" is no longer read here — "
                    f"{UPDATE_HINT}",
                )
            )
        for key in sorted((set(live) | locked) - all_emitted):
            findings.append(
                Finding(
                    RULE_ID,
                    gate_path,
                    live.get(key, 1),
                    f"gate reads key \"{key}\" that no locked emitter "
                    "emits — the check would KeyError (or silently pass) "
                    "in CI",
                )
            )
    return findings


def regenerate(scan):
    """Fresh lock contents from live extraction, preserving the existing
    lock's gate ignore-lists and file sets. Used by ``--update-lock``."""
    old = load_lock(scan) or {"emitters": {}, "gates": {}}
    lock = {
        "_doc": (
            "Committed telemetry-schema contract, enforced by "
            "ci/analysis/schema_lock.py (rule: schema-lock). Regenerate "
            "with `python3 ci/analysis/oats_tidy.py schema-lock "
            "--update-lock` and review the diff; CI never writes this file."
        ),
        "version": 1,
        "emitters": {},
        "gates": {},
    }
    for emitter_path in sorted(old.get("emitters", {})):
        src = scan.file(emitter_path)
        keys = sorted(extract_emitted_keys(src)) if src is not None else []
        lock["emitters"][emitter_path] = keys
    for gate_path, entry in sorted(old.get("gates", {}).items()):
        ignore = sorted(entry.get("ignore", []))
        text = _read_text(scan, gate_path)
        reads = (
            sorted(k for k in extract_gate_reads(text) if k not in set(ignore))
            if text is not None
            else []
        )
        lock["gates"][gate_path] = {"reads": reads, "ignore": ignore}
    return lock


def write_lock(scan):
    lock = regenerate(scan)
    full = os.path.join(scan.root, LOCK_PATH)
    with open(full, "w", encoding="utf-8") as f:
        json.dump(lock, f, indent=2, sort_keys=False)
        f.write("\n")
    return full
