//! End-to-end driver (the system-prompt mandated E2E validation): proves all
//! three layers compose on a real small workload.
//!
//! 1. **Train** a transformer LM from scratch on the synthetic corpus by
//!    driving the JAX/Pallas-lowered `train_step` HLO artifact through the
//!    PJRT runtime (L2/L1 under rust control); logs the loss curve.
//! 2. **Compress** the trained model with OATS and every baseline at ρ=0.5
//!    through the L3 coordinator pipeline (Algorithm 2).
//! 3. **Evaluate** perplexity + task suites, and **serve** the compressed
//!    model through the batched engine, reporting throughput.
//!
//! Run: `make artifacts && cargo run --release --example e2e_pipeline`
//! (add `-- --quick` for a CI-sized run; `--preset small|base` to scale up).

use oats::cli::Args;
use oats::config::{CompressConfig, Method};
use oats::coordinator::pipeline::compress_clone;
use oats::experiments::Ctx;
use oats::report::{pct, ppl, speedup, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let quick = args.bool_flag("quick");
    let preset = args.flag_or("preset", "tiny");
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    if !oats::runtime::Engine::available(&root.join("artifacts").join(preset)) {
        eprintln!("artifacts/{preset} missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let mut ctx = Ctx::new(&root, quick);

    // ── 1. train via the PJRT train_step artifact ──
    println!("━━ stage 1: training '{preset}' via PJRT train_step artifact ━━");
    let model = ctx.model(preset)?; // trains on first call, caches to models/
    let curve_path = root.join("models").join(preset).join("loss_curve.json");
    if let Ok(s) = std::fs::read_to_string(&curve_path) {
        let curve = oats::json::parse(&s)?;
        let arr = curve.as_arr().unwrap_or(&[]).to_vec();
        let pick = |i: usize| arr.get(i).and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
        let n = arr.len();
        println!("loss curve ({n} steps):");
        for frac in [0.0, 0.1, 0.25, 0.5, 0.75, 1.0] {
            let i = ((n.saturating_sub(1)) as f64 * frac) as usize;
            println!("  step {:>6}: {:.4}", i, pick(i));
        }
    }
    let corpus = oats::data::SyntheticCorpus::new(ctx.corpus(preset)?.cfg.clone());
    let dense_row =
        oats::eval::evaluate(&model, &corpus, "Dense", ctx.eval_batches(), ctx.eval_probes());
    println!(
        "dense model: ppl={:.2} hard={:.1}% easy={:.1}%\n",
        dense_row.ppl, dense_row.hard, dense_row.easy
    );

    // ── 2. compress with every method at ρ=0.5 ──
    println!("━━ stage 2: compression (ρ=0.5, κ=0.25, N={}) ━━", if quick { 8 } else { 80 });
    let calib = ctx.calib(preset)?;
    let mut t = Table::new(
        "E2E — ρ=0.5 compression comparison",
        &["Method", "Hard", "Easy", "PPL", "Achieved ρ", "Compress s"],
    );
    t.row(vec![
        "Dense".into(),
        pct(dense_row.hard),
        pct(dense_row.easy),
        ppl(dense_row.ppl),
        "0%".into(),
        "-".into(),
    ]);
    let mut compressed_oats = None;
    for method in Method::all_pruners() {
        let cfg = CompressConfig {
            method,
            rate: 0.5,
            rank_ratio: 0.25,
            iters: if quick { 8 } else { 80 },
            ..Default::default()
        };
        let (cm, report) = compress_clone(&model, &calib, &cfg, 6)?;
        let row = oats::eval::evaluate(&cm, &corpus, method.name(), ctx.eval_batches(), ctx.eval_probes());
        t.row(vec![
            method.name().into(),
            pct(row.hard),
            pct(row.easy),
            ppl(row.ppl),
            format!("{:.1}%", cm.achieved_compression() * 100.0),
            format!("{:.1}", report.total_seconds),
        ]);
        if method == Method::Oats {
            compressed_oats = Some(cm);
        }
    }
    t.print();
    ctx.record(&t.to_json());

    // ── 3. serve the compressed model ──
    println!("\n━━ stage 3: batched serving (dense vs OATS weights) ━━");
    let oats_model = compressed_oats.unwrap();
    let n_req = if quick { 16 } else { 64 };
    let tp_dense = oats::experiments::speed::decode_throughput(&model, n_req, 4);
    let tp_oats = oats::experiments::speed::decode_throughput(&oats_model, n_req, 4);
    println!("dense engine: {tp_dense:.1} tokens/s");
    println!(
        "OATS engine:  {tp_oats:.1} tokens/s  ({} vs dense)",
        speedup(tp_oats / tp_dense)
    );
    println!("\nE2E pipeline complete — all three layers exercised.");
    Ok(())
}
