//! Section 5 driver: train (or load) the ViT, compress it 50% with OATS,
//! split the compressed model into sparse-only and low-rank-only paths, and
//! visualize the attention rollout of each (Figures 3–4). Writes PGM
//! heatmaps under results/rollout and prints ASCII art + cosine-separation
//! statistics.
//!
//! Run: `make artifacts && cargo run --release --example vit_rollout [-- --quick]`

use oats::cli::Args;
use oats::experiments::{vision, Ctx};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut ctx = Ctx::new(&root, args.bool_flag("quick"));
    if !oats::runtime::Engine::available(&ctx.artifacts.join("tiny")) {
        eprintln!("artifacts/tiny missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let out = root.join(args.flag_or("out", "results/rollout"));
    let t = vision::rollout_analysis(&mut ctx, &out)?;
    t.print();
    ctx.record(&t.to_json());
    println!("\nPGM heatmaps: {}", out.display());
    println!(
        "Low cos(S, L) values mean the sparse and low-rank terms attend to\n\
         different image regions — the paper's segmentation observation."
    );
    Ok(())
}
