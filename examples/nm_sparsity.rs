//! Figure 2 driver: N:M structured sparsity. Baselines at 2:4 (fixed 50%
//! compression) vs OATS at 2:8 with the rank ratio sweeping the
//! compression–accuracy trade-off — the paper's point that the low-rank
//! term converts a *fixed* N:M rate into a *tunable* one.
//!
//! Run: `cargo run --release --example nm_sparsity [-- --quick]`

use oats::cli::Args;
use oats::experiments::{sweeps, Ctx};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut ctx = Ctx::new(&root, args.bool_flag("quick"));
    let preset = args.flag_or("preset", if ctx.quick { "tiny" } else { "small" });
    if !oats::runtime::Engine::available(&ctx.artifacts.join(preset)) {
        eprintln!("artifacts/{preset} missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let t = sweeps::nm_sweep(&mut ctx, preset)?;
    t.print();
    ctx.record(&t.to_json());
    println!(
        "\nReading the table: the 2:4 baselines are pinned at ~50% compression;\n\
         OATS' 2:8 rows trade compression for accuracy via κ (paper Figure 2)."
    );
    Ok(())
}
