//! Table 7 / Table 14 driver: CPU serving throughput of the compressed
//! engine. Same model, same batching/decode code — only the weight-format
//! kernels differ (dense GEMV vs CSR vs fused sparse+low-rank).
//!
//! Run: `cargo run --release --example serve_throughput [-- --seq] [--quick]`

use oats::cli::Args;
use oats::experiments::{speed, Ctx};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut ctx = Ctx::new(&root, args.bool_flag("quick"));
    let preset = args.flag_or("preset", if ctx.quick { "tiny" } else { "small" });
    if !oats::runtime::Engine::available(&ctx.artifacts.join(preset)) {
        eprintln!("artifacts/{preset} missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let seq = args.bool_flag("seq");
    let t = speed::throughput_table(&mut ctx, preset, seq)?;
    t.print();
    ctx.record(&t.to_json());
    if !seq {
        println!(
            "\nPaper Table 7's shape: OATS > unstructured > dense at every ρ,\n\
             because κ of the budget moves from irregular CSR work into dense\n\
             skinny matmuls. Run with --seq for the Table 14 (long-sequence)\n\
             regime where the gap closes."
        );
    }
    Ok(())
}
