//! Quickstart: compress a single weight matrix with OATS and compare the
//! outlier-weighted reconstruction error against Wanda, SparseGPT and
//! magnitude pruning — the paper's core claim in ~60 lines.
//!
//! Run: `cargo run --release --example quickstart`

use oats::compress::{compress_layer, CalibStats};
use oats::config::{CompressConfig, Method};
use oats::tensor::Matrix;
use oats::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);
    let (dout, din) = (256, 256);

    // A weight matrix and calibration activations with outlier features
    // (a few columns carry 30× the typical magnitude — the phenomenon the
    // paper's D-scaling targets, §2.3).
    let w = Matrix::randn(dout, din, 0.02, &mut rng);
    let mut x = Matrix::randn(512, din, 1.0, &mut rng);
    for c in [3usize, 77, 191] {
        for r in 0..x.rows {
            *x.at_mut(r, c) *= 30.0;
        }
    }
    let stats = CalibStats::from_activations(&x);
    let d = stats.scale_d();

    println!("compressing a {dout}x{din} layer to 50% with each method\n");
    println!(
        "{:<12} {:>14} {:>18} {:>10}",
        "method", "‖ΔW‖/‖W‖", "‖ΔW·D‖/‖W·D‖", "params"
    );
    for method in [Method::Magnitude, Method::SparseGpt, Method::Wanda, Method::DsNoT, Method::Oats]
    {
        let cfg = CompressConfig {
            method,
            rate: 0.5,
            rank_ratio: 0.25,
            iters: 40,
            ..Default::default()
        };
        let out = compress_layer(&w, &stats, &cfg)?;
        let wc = out.to_dense();
        let mut diff = w.clone();
        diff.axpy(-1.0, &wc);
        let rel = diff.fro_norm() / w.fro_norm();
        // The error that matters downstream: weighted by activation scale.
        let wd = w.mul_columns(&d);
        let rel_d = diff.mul_columns(&d).fro_norm() / wd.fro_norm();
        println!(
            "{:<12} {:>14.4} {:>18.4} {:>10}",
            method.name(),
            rel,
            rel_d,
            out.param_count()
        );
    }
    println!(
        "\nOATS should win on the activation-weighted column (the loss-relevant\n\
         metric), by combining the D-scaled sparse term with a low-rank term."
    );
    Ok(())
}
